"""Tag expression engine + solver: normalization soundness (hypothesis)
and counterexample validity."""
import random

import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.solver import (Status, prove_injective, prove_tags_distinct,
                               prove_tags_equal, prove_zero)
from repro.core.tags import BOT, TOP, Expr, Var, app, floordiv, make_tag, \
    merge, mod

V = [Var("x", 7), Var("y", 12), Var("z", 33)]


@st.composite
def exprs(draw, depth=0):
    if depth >= 3:
        return Expr.of(draw(st.sampled_from(V + list(range(-3, 4)))))
    kind = draw(st.integers(0, 5))
    if kind == 0:
        return Expr.of(draw(st.sampled_from(V)))
    if kind == 1:
        return Expr.of(draw(st.integers(-8, 8)))
    a = draw(exprs(depth=depth + 1))
    b = draw(exprs(depth=depth + 1))
    if kind == 2:
        return a + b
    if kind == 3:
        return a - b
    if kind == 4:
        return a * draw(st.integers(-4, 4))
    op = draw(st.sampled_from([floordiv, mod]))
    return op(a, draw(st.integers(1, 9)))


def _env(seed):
    rng = random.Random(seed)
    return {v: rng.randrange(v.extent) for v in V}


@given(exprs(), st.integers(0, 1000))
@settings(max_examples=300, deadline=None)
def test_normalization_preserves_evaluation(e, seed):
    """Whatever rewriting happened during construction, the normal form
    evaluates identically to direct (python-int) semantics — checked by
    rebuilding e - e and evaluating (always 0)."""
    env = _env(seed)
    d = e - e
    assert d.evaluate(env) == 0


@given(exprs(), exprs(), st.integers(0, 100))
@settings(max_examples=200, deadline=None)
def test_prove_zero_soundness(a, b, seed):
    """PROVEN implies equal on random samples; VIOLATED's counterexample
    actually distinguishes the expressions."""
    res = prove_zero([a - b])
    if res.status is Status.PROVEN:
        for s in range(5):
            env = _env(seed + s)
            assert a.evaluate(env) == b.evaluate(env)
    elif res.status is Status.VIOLATED and res.counterexample is not None:
        env = dict(res.counterexample.env)
        for v in V:
            env.setdefault(v, 0)
        assert (a - b).evaluate(env) != 0


def test_mod_simplification():
    x = Var("x", 7)
    assert mod(Expr.of(x) * 12, 12) == Expr.of(0)
    assert mod(Expr.of(x), 7) == Expr.of(x)            # extent <= k
    assert floordiv(Expr.of(x) * 12 + 5, 12) == Expr.of(x)
    assert floordiv(Expr.of(x), 1) == Expr.of(x)


def test_merge_lattice():
    t = make_tag(Expr.of(V[0]))
    t2 = make_tag(Expr.of(V[1]))
    assert merge(BOT, t) is t
    assert merge(t, BOT) is t
    assert merge(TOP, t) is TOP
    assert merge(t, t) is t
    assert merge(t, t2) is TOP


def test_uninterpreted_tables_distinguished():
    x = Var("x", 64)
    same = prove_tags_equal(make_tag(app("perm", x, 64)),
                            make_tag(app("perm", x, 64)))
    assert same.ok
    diff = prove_tags_equal(make_tag(app("perm", x, 64)),
                            make_tag(app("perm2", x, 64)))
    assert diff.status is Status.VIOLATED


def test_injectivity():
    i, j = Var("i", 8), Var("j", 8)
    ok = prove_injective(Expr.of(i) * 8 + j, [i, j])
    assert ok.ok
    bad = prove_injective(Expr.of(i) * 4 + j, [i, j])  # overlapping reach
    assert bad.status is Status.VIOLATED


def test_distinctness():
    i = Var("i", 8)
    res = prove_tags_distinct(make_tag(Expr.of(i)),
                              make_tag(Expr.of(i) + 9))
    assert res.ok
    res2 = prove_tags_distinct(make_tag(Expr.of(i)),
                               make_tag(Expr.of(6 - i)))
    assert res2.status is Status.VIOLATED
