"""Family invariant templates: correct configs pass, every injectable bug
class is caught with a concrete counterexample (the paper's core claim)."""
import pytest

from repro.core.invariants import (FlashAttentionConfig,
                                   FlashAttentionProblem, GemmConfig,
                                   GemmProblem, MoEConfig, MoEProblem,
                                   verify_flash_attention, verify_gemm,
                                   verify_moe)

GEMM_PROB = GemmProblem(512, 512, 1024)
FA_PROB = FlashAttentionProblem(2, 8, 2, 2048, 2048, 128)
MOE_PROB = MoEProblem(4096, 1024, 2048, 16, 2)


class TestGemm:
    def test_correct_passes(self):
        assert verify_gemm(GemmConfig(), GEMM_PROB).ok

    @pytest.mark.parametrize("cfg", [
        GemmConfig(stagger_k=True),
        GemmConfig(split_k=2),
        GemmConfig(bm=256, bn=256, bk=256),
        GemmConfig(split_k=4, bm=128),
    ])
    def test_variants_pass(self, cfg):
        r = verify_gemm(cfg, GemmProblem(1024, 1024, 2048))
        assert r.hard_ok, r.render()

    @pytest.mark.parametrize("bug", ["swap_b_index", "acc_depends_k",
                                     "grid_short", "missing_init"])
    def test_bugs_caught(self, bug):
        r = verify_gemm(GemmConfig(), GEMM_PROB, inject_bug=bug)
        assert not r.hard_ok

    def test_stagger_mismatch_caught(self):
        r = verify_gemm(GemmConfig(stagger_k=True), GEMM_PROB,
                        inject_bug="stagger_mismatch")
        assert not r.hard_ok

    def test_counterexample_is_concrete(self):
        r = verify_gemm(GemmConfig(), GEMM_PROB, inject_bug="swap_b_index")
        viol = [res for _, res in r.report.results if not res.ok]
        assert viol and viol[0].counterexample is not None
        # the counterexample names grid step + element + both tags
        assert viol[0].counterexample.env

    def test_structural_alignment_warns(self):
        r = verify_gemm(GemmConfig(bk=64), GEMM_PROB)
        assert r.hard_ok and not r.ok          # warning, not violation
        assert any(s.kind == "alignment" for s in r.structural)

    def test_vmem_budget(self):
        r = verify_gemm(GemmConfig(bm=2048, bn=2048, bk=1024),
                        GemmProblem(4096, 4096, 4096))
        assert any(s.kind == "vmem" for s in r.structural)


class TestFlashAttention:
    def test_correct_passes(self):
        assert verify_flash_attention(FlashAttentionConfig(), FA_PROB).ok

    def test_transv_passes(self):
        cfg = FlashAttentionConfig(block_kv=128, v_transposed_staging=True)
        assert verify_flash_attention(cfg, FA_PROB).ok

    @pytest.mark.parametrize("bug", ["wrong_kv_head", "m_depends_kv",
                                     "q_block_offset"])
    def test_bugs_caught(self, bug):
        r = verify_flash_attention(FlashAttentionConfig(), FA_PROB,
                                   inject_bug=bug)
        assert not r.hard_ok

    def test_missing_transpose_caught(self):
        cfg = FlashAttentionConfig(block_kv=128, v_transposed_staging=True)
        r = verify_flash_attention(cfg, FA_PROB,
                                   inject_bug="missing_transpose")
        assert not r.hard_ok

    def test_skip_without_causal_flagged(self):
        cfg = FlashAttentionConfig(causal_block_skip=True)
        prob = FlashAttentionProblem(2, 8, 2, 2048, 2048, 128, causal=False)
        r = verify_flash_attention(cfg, prob)
        assert any(s.kind == "masking" for s in r.structural)


class TestMoE:
    def test_correct_passes(self):
        assert verify_moe(MoEConfig(), MOE_PROB).ok

    @pytest.mark.parametrize("bug", ["w_by_block_index",
                                     "combine_other_table",
                                     "gate_unpermuted", "down_f_offset",
                                     "y_depends_f"])
    def test_bugs_caught(self, bug):
        r = verify_moe(MoEConfig(), MOE_PROB, inject_bug=bug)
        assert not r.hard_ok
