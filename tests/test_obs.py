"""Observability: the log2 histogram's merge laws (hypothesis, plus a
seeded twin that always runs), bounded-error quantiles, two-process
contention on a shared histogram file, the tracer's ring bounding /
well-nestedness / zero-allocation disabled path, and the Prometheus
exposition + HTTP scrape endpoint."""
import gc
import json
import subprocess
import sys
import urllib.error
import urllib.request
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.obs.export import MetricsServer, prometheus_text
from repro.obs.hist import (N_BUCKETS, LogHistogram, bucket_index,
                            bucket_upper, merge_dicts,
                            quantiles_from_values)
from repro.serve.metrics import ServingMetrics

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _hist(values):
    h = LogHistogram()
    for v in values:
        h.record(v)
    return h


# ---------------------------------------------------------------------------
# Bucket layout
# ---------------------------------------------------------------------------

class TestBuckets:
    def test_bucket_boundaries(self):
        assert bucket_index(-5) == 0
        assert bucket_index(0) == 0
        assert bucket_index(1) == 1
        assert bucket_index(2) == 2
        assert bucket_index(3) == 2
        assert bucket_index(4) == 3
        assert bucket_index(2**70) == N_BUCKETS - 1

    def test_value_lands_within_its_bucket_bounds(self):
        for v in list(range(200)) + [10**6, 2**40]:
            i = bucket_index(v)
            assert v <= bucket_upper(i)
            if i > 1:
                assert v > bucket_upper(i - 1)

    def test_upper_bound_errs_by_at_most_one_bucket_width(self):
        """The reported bound is < 2x the true value (log2 buckets)."""
        for v in range(1, 5000):
            assert v <= bucket_upper(bucket_index(v)) < 2 * v


# ---------------------------------------------------------------------------
# Merge laws: hypothesis when available, seeded twin always
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # pragma: no cover - dev-only dependency
    st = None

if st is not None:
    _VALUES = st.lists(st.integers(0, 2**40), max_size=50)

    class TestMergeLawsHypothesis:
        @settings(max_examples=200, deadline=None)
        @given(a=_VALUES, b=_VALUES)
        def test_commutative(self, a, b):
            x, y = _hist(a).merge(_hist(b)), _hist(b).merge(_hist(a))
            assert x.counts == y.counts and x.total == y.total

        @settings(max_examples=200, deadline=None)
        @given(a=_VALUES, b=_VALUES, c=_VALUES)
        def test_associative(self, a, b, c):
            ha, hb, hc = _hist(a), _hist(b), _hist(c)
            x, y = ha.merge(hb).merge(hc), ha.merge(hb.merge(hc))
            assert x.counts == y.counts and x.total == y.total

        @settings(max_examples=100, deadline=None)
        @given(a=_VALUES)
        def test_empty_is_identity(self, a):
            h = _hist(a)
            m = h.merge(LogHistogram())
            assert m.counts == h.counts and m.total == h.total

        @settings(max_examples=200, deadline=None)
        @given(a=_VALUES, b=_VALUES)
        def test_merge_then_quantile_equals_record_all(self, a, b):
            """Sharded recording then merging answers every quantile
            exactly as one histogram that saw everything — the property
            fslock.merge_save leans on."""
            merged, whole = _hist(a).merge(_hist(b)), _hist(a + b)
            assert merged.counts == whole.counts
            for q in (0.5, 0.9, 0.95, 0.99):
                assert merged.quantile(q) == whole.quantile(q)
else:
    class TestMergeLawsHypothesis:
        @pytest.mark.skip(reason="hypothesis not installed — pip "
                          "install -r requirements-dev.txt")
        def test_hypothesis_properties(self):
            pass


class TestMergeLawsSeeded:
    def test_merge_laws_and_quantiles_seeded(self):
        """Hypothesis-free twin: seeded shards must merge order-free
        and answer quantiles like the unsharded histogram."""
        rng = np.random.default_rng(0)
        for _ in range(25):
            shards = [[int(v) for v in
                       rng.integers(0, 2**20, size=rng.integers(0, 40))]
                      for _ in range(4)]
            hs = [_hist(s) for s in shards]
            fwd = hs[0].merge(hs[1]).merge(hs[2]).merge(hs[3])
            rev = hs[3].merge(hs[2]).merge(hs[1]).merge(hs[0])
            whole = _hist([v for s in shards for v in s])
            assert fwd.counts == rev.counts == whole.counts
            assert fwd.total == rev.total == whole.total
            for q in (0.5, 0.95, 0.99):
                assert fwd.quantile(q) == whole.quantile(q)


# ---------------------------------------------------------------------------
# Quantiles: exact vs the raw-value reference, error bound
# ---------------------------------------------------------------------------

class TestQuantiles:
    def test_matches_reference_nearest_rank(self):
        rng = np.random.default_rng(1)
        for _ in range(20):
            values = [int(v) for v in
                      rng.integers(0, 10**6, size=rng.integers(1, 200))]
            h = _hist(values)
            for q in (0.01, 0.5, 0.9, 0.95, 0.99, 1.0):
                assert h.quantile(q) == quantiles_from_values(values, q)

    def test_error_bounded_by_bucket_width(self):
        """The estimate is >= the true nearest-rank value and < 2x it
        (one log2 bucket of slack)."""
        rng = np.random.default_rng(2)
        for _ in range(20):
            values = sorted(int(v) for v in
                            rng.integers(1, 10**6, size=100))
            h = _hist(values)
            for q in (0.5, 0.95, 0.99):
                true = values[int(np.ceil(q * len(values))) - 1]
                est = h.quantile(q)
                assert true <= est < 2 * true, (q, true, est)

    def test_empty_histogram_reports_zero(self):
        h = LogHistogram()
        assert h.quantile(0.99) == 0
        assert h.summary() == {"count": 0, "sum": 0, "p50": 0,
                               "p95": 0, "p99": 0}

    def test_dict_round_trip_and_merge_dicts(self):
        h = _hist([0, 1, 5, 5, 300])
        d = h.to_dict()
        assert d["scheme"] == "log2"
        back = LogHistogram.from_dict(d)
        assert back.counts == h.counts and back.total == h.total
        g = _hist([7, 9000])
        assert merge_dicts(d, g.to_dict()) == h.merge(g).to_dict()
        with pytest.raises(ValueError, match="scheme"):
            LogHistogram.from_dict({"scheme": "linear", "counts": {}})


# ---------------------------------------------------------------------------
# Two processes hammering one shared histogram file
# ---------------------------------------------------------------------------

# each subprocess folds single-observation histograms into the shared
# file via merge_save_hist; any lost read-merge-write round would drop
# observations from the final counts
_HAMMER = """
import sys
sys.path.insert(0, sys.argv[4])
from repro.obs.hist import LogHistogram, merge_save_hist
wid, rounds, path = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
for i in range(rounds):
    h = LogHistogram()
    h.record(wid * 100000 + i)
    merge_save_hist(path, h)
"""


class TestSharedHistogramFile:
    @pytest.mark.multiproc
    def test_two_processes_lose_no_observations(self, tmp_path):
        path = tmp_path / "latency.json"
        rounds = 40
        procs = [subprocess.Popen(
            [sys.executable, "-c", _HAMMER, str(wid), str(rounds),
             str(path), SRC]) for wid in (1, 2)]
        for p in procs:
            assert p.wait(timeout=120) == 0
        h = LogHistogram.from_dict(json.loads(path.read_text()))
        assert h.count == 2 * rounds, \
            f"lost observations under contention: {h.count}"
        expect = _hist([w * 100000 + i for w in (1, 2)
                        for i in range(rounds)])
        assert h.counts == expect.counts and h.total == expect.total


# ---------------------------------------------------------------------------
# Tracer: ring bounding, nesting, chrome round-trip, disabled path
# ---------------------------------------------------------------------------

class TestTracer:
    def teardown_method(self):
        obs.disable()

    def test_disabled_is_the_default_and_hands_out_one_singleton(self):
        assert not obs.enabled()
        a, b = obs.span("x"), obs.span("y", {"k": 1})
        assert a is b

    def test_ring_is_bounded(self):
        obs.enable(clock=obs.TickClock(), capacity=8)
        for i in range(20):
            with obs.span("ev", {"i": i}):
                pass
        evs = obs.tracer().events()
        assert len(evs) == 8
        assert [e["args"]["i"] for e in evs] == list(range(12, 20))

    def test_nested_spans_round_trip_through_chrome_schema(self, tmp_path):
        obs.enable(clock=obs.TickClock(), pid=7)
        with obs.span("outer", {"tick": 1}):
            with obs.span("inner"):
                pass
            with obs.span("inner"):
                pass
        path = tmp_path / "t.trace.json"
        obs.tracer().save(path)
        trace = json.loads(path.read_text())
        assert trace["displayTimeUnit"] == "ms"
        evs = trace["traceEvents"]
        assert [e["name"] for e in evs] == ["inner", "inner", "outer"]
        assert all(e["ph"] == "X" and e["pid"] == 7 for e in evs)
        assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in evs)
        assert obs.well_nested(evs)
        outer = evs[-1]
        assert outer["args"] == {"tick": 1}
        for inner in evs[:2]:
            assert outer["ts"] <= inner["ts"]
            assert inner["ts"] + inner["dur"] \
                <= outer["ts"] + outer["dur"]

    def test_well_nested_rejects_partial_overlap_and_negatives(self):
        lane = {"ph": "X", "pid": 0, "tid": 0}
        good = [dict(lane, name="a", ts=0, dur=10),
                dict(lane, name="b", ts=2, dur=3),
                dict(lane, name="c", ts=6, dur=4)]
        assert obs.well_nested(good)
        overlap = [dict(lane, name="a", ts=0, dur=10),
                   dict(lane, name="b", ts=5, dur=10)]
        assert not obs.well_nested(overlap)
        assert not obs.well_nested([dict(lane, name="a", ts=-1, dur=2)])
        assert not obs.well_nested([dict(lane, name="a", ts=0, dur=-2)])
        # the same two intervals on different lanes are fine
        other = [dict(lane, name="a", ts=0, dur=10),
                 dict(dict(lane, tid=1), name="b", ts=5, dur=10)]
        assert obs.well_nested(other)

    def test_set_merges_late_attrs(self):
        obs.enable(clock=obs.TickClock())
        with obs.span("s", {"a": 1}) as sp:
            sp.set(b=2)
        assert obs.tracer().events()[0]["args"] == {"a": 1, "b": 2}

    def test_tick_clock_is_deterministic(self):
        a, b = obs.TickClock(), obs.TickClock()
        assert [a() for _ in range(5)] == [b() for _ in range(5)]
        assert obs.TickClock(step_us=50)() == pytest.approx(50e-6)

    @pytest.mark.skipif(not hasattr(sys, "getallocatedblocks"),
                        reason="needs sys.getallocatedblocks")
    def test_disabled_span_allocates_nothing(self):
        """The hot-path guarantee, pinned as an allocation budget over
        a tight loop — not a timing test.  The disabled path must hand
        out the shared null span without materializing anything."""
        assert not obs.enabled()
        span = obs.span
        for _ in range(1000):          # warm caches / free lists
            with span("warmup"):
                pass
        gc.collect()
        before = sys.getallocatedblocks()
        for _ in range(100_000):
            with span("hot"):
                pass
        delta = sys.getallocatedblocks() - before
        assert delta <= 16, \
            f"disabled span() allocated {delta} blocks over the loop"


# ---------------------------------------------------------------------------
# Prometheus exposition + scrape endpoint
# ---------------------------------------------------------------------------

def _sample_metrics():
    m = ServingMetrics(24, "paged")
    for t in range(10):
        m.record_tick(queue_depth=1, active=2, occupancy=12,
                      decode_tokens=2, step_time_us=40 + t)
    m.record_latency("ttft", 3)
    m.record_latency("ttft", 9)
    m.record_latency("tpot", 1)
    m.record_latency("queue_wait", 0)
    return m


class TestPrometheus:
    def test_counters_gauges_and_labels(self):
        text = prometheus_text(_sample_metrics().snapshot())
        assert 'argus_ticks_total{engine="paged"} 10' in text
        assert 'argus_decode_tokens_total{engine="paged"} 20' in text
        assert 'argus_capacity{engine="paged"} 24' in text
        assert 'argus_occupancy_peak{engine="paged"} 12' in text
        assert "# TYPE argus_ticks_total counter" in text
        assert "# TYPE argus_ttft histogram" in text

    def test_histogram_buckets_are_cumulative_and_end_at_inf(self):
        text = prometheus_text(_sample_metrics().snapshot())
        for name, count, total in (("ttft", 2, 12), ("tpot", 1, 1),
                                   ("queue_wait", 1, 0),
                                   ("step_time", 10, sum(range(40, 50)))):
            lines = [ln for ln in text.splitlines()
                     if ln.startswith(f"argus_{name}_bucket")]
            counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
            assert counts == sorted(counts), f"{name}: not cumulative"
            assert lines[-1].startswith(
                f'argus_{name}_bucket{{engine="paged",le="+Inf"}}')
            assert counts[-1] == count
            assert f'argus_{name}_count{{engine="paged"}} {count}' in text
            assert f'argus_{name}_sum{{engine="paged"}} {total}' in text

    def test_v2_snapshot_renders_without_latency(self):
        snap = _sample_metrics().snapshot()
        del snap["latency"]
        snap["schema"] = 2
        text = prometheus_text(snap)
        assert "argus_ticks_total" in text
        assert "_bucket" not in text

    def test_metrics_server_scrape(self):
        m = _sample_metrics()
        srv = MetricsServer(lambda: prometheus_text(m.snapshot()), port=0)
        try:
            url = f"http://127.0.0.1:{srv.port}/metrics"
            with urllib.request.urlopen(url, timeout=10) as resp:
                assert resp.status == 200
                assert resp.headers["Content-Type"].startswith(
                    "text/plain; version=0.0.4")
                body = resp.read().decode()
            assert 'argus_ticks_total{engine="paged"} 10' in body
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://127.0.0.1:{srv.port}/nope", timeout=10)
        finally:
            srv.close()
