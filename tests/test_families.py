"""Registry round-trip: every registered family drives the full pipeline
(config/problem construction → build_program → verify) for a known-good
and every known-bad (injected-bug) config, and the registry's auxiliary
hooks (config dispatch, skills, cost, bug menus) are coherent."""
import dataclasses

import pytest

from repro.core import dsl
from repro.core.families import (all_families, family_for_config,
                                 family_names, get_family)

# One bug-friendly (config, problem) fixture per family: every entry in
# the family's injectable-bug menu must apply (e.g. GQA shapes so
# wrong_kv_head is expressible, stagger_k on so stagger_mismatch is).
FIXTURES = {
    "gemm": (lambda f: f.config_cls(stagger_k=True),
             lambda f: f.problem_cls(512, 512, 1024)),
    "flash_attention": (lambda f: f.config_cls(),
                        lambda f: f.problem_cls(2, 8, 2, 2048, 2048, 128)),
    "flash_decode": (lambda f: f.config_cls(kv_splits=8),
                     lambda f: f.problem_cls(2, 8, 2, 1024, 128)),
    "moe": (lambda f: f.config_cls(),
            lambda f: f.problem_cls(4096, 1024, 2048, 16, 2)),
    "ssd": (lambda f: f.config_cls(chunk=128),
            lambda f: f.problem_cls(4, 1024, 64, 64)),
}


def _fixture(name):
    fam = get_family(name)
    mk_cfg, mk_prob = FIXTURES[name]
    return fam, mk_cfg(fam), mk_prob(fam)


def test_every_registered_family_has_a_fixture():
    assert set(family_names()) == set(FIXTURES), \
        "add a round-trip fixture for every registered family"


@pytest.mark.parametrize("name", sorted(FIXTURES))
class TestRoundTrip:
    def test_known_good_config_verifies(self, name):
        fam, cfg, prob = _fixture(name)
        prog = fam.build_program(cfg, prob)
        assert isinstance(prog, dsl.TileProgram)
        assert any(type(op).__name__.startswith("Assert")
                   for op in prog.ops), "family declares no invariants"
        res = fam.verify(cfg, prob)
        assert res.hard_ok, res.render()

    def test_every_injectable_bug_is_caught(self, name):
        fam, cfg, prob = _fixture(name)
        menu = fam.bugs_for(cfg, prob)
        assert set(menu) <= set(fam.injectable_bugs)
        assert menu, "fixture exposes no injectable bugs"
        for bug in menu:
            res = fam.verify(cfg, prob, inject_bug=bug)
            assert not res.hard_ok, \
                f"{name}: injected bug {bug!r} slipped through"

    def test_config_dispatch_and_dataclasses(self, name):
        fam, cfg, prob = _fixture(name)
        assert family_for_config(cfg) is fam
        assert dataclasses.is_dataclass(cfg) and dataclasses.is_dataclass(
            prob)
        # frozen, hashable configs are what make engine memo keys sound
        first_field = dataclasses.fields(cfg)[0].name
        with pytest.raises(dataclasses.FrozenInstanceError):
            setattr(cfg, first_field, 0)
        assert hash(cfg) is not None and hash(prob) is not None

    def test_cost_and_skills_hooks(self, name):
        fam, cfg, prob = _fixture(name)
        est = fam.cost(cfg, prob)
        assert est.time_s > 0 and est.flops > 0
        assert fam.skills, "family registers no skills"
        for skill in fam.skills:
            assert name in skill.families
            for label, new_cfg in skill.contexts(cfg, prob):
                assert isinstance(new_cfg, fam.config_cls), \
                    f"{skill.name} context {label} left the config space"


def test_registry_is_complete_and_consistent():
    fams = all_families()
    assert len(fams) >= 5
    for fam in fams:
        assert get_family(fam.name) is fam
        assert fam.build_program is not None
        assert fam.structural is not None and fam.cost is not None


def test_unknown_family_raises():
    with pytest.raises(KeyError):
        get_family("conv3d")
