"""Registry round-trip: every registered family drives the full pipeline
(config/problem construction → build_program → verify) for a known-good
and every known-bad (injected-bug) config, and the registry's auxiliary
hooks (config dispatch, skills, cost, bug menus) are coherent.

The suite parametrizes over :func:`repro.core.families.family_names` at
collection time, so a newly registered family gets every property below
for free.  ``FIXTURES`` only *overrides* the default fixture (the
family's own ``example()``) where bug-friendly shapes are needed —
e.g. GQA head counts so ``wrong_kv_head`` is expressible, or
``stagger_k`` on so ``stagger_mismatch`` is."""
import dataclasses
import math

import pytest

from repro.core import dsl
from repro.core.families import (all_families, family_for_config,
                                 family_names, get_family)

# Bug-friendly (config, problem) overrides.  A family without an entry
# here must provide an ``example()`` exposing at least one injectable
# bug — the round-trip below enforces it either way.
FIXTURES = {
    "gemm": (lambda f: f.config_cls(stagger_k=True),
             lambda f: f.problem_cls(512, 512, 1024)),
    "flash_attention": (lambda f: f.config_cls(),
                        lambda f: f.problem_cls(2, 8, 2, 2048, 2048, 128)),
    "flash_decode": (lambda f: f.config_cls(kv_splits=8),
                     lambda f: f.problem_cls(2, 8, 2, 1024, 128)),
    "moe": (lambda f: f.config_cls(),
            lambda f: f.problem_cls(4096, 1024, 2048, 16, 2)),
    "ssd": (lambda f: f.config_cls(chunk=128),
            lambda f: f.problem_cls(4, 1024, 64, 64)),
    "quant_gemm": (lambda f: f.config_cls(),
                   lambda f: f.problem_cls(512, 512, 1024, group=256)),
    "paged_attention": (
        lambda f: f.config_cls(block_pages=2),
        lambda f: f.problem_cls(2, 8, 2, 1024, 128, 20, 128)),
}

ALL_FAMILIES = sorted(family_names())


def _fixture(name):
    fam = get_family(name)
    if name in FIXTURES:
        mk_cfg, mk_prob = FIXTURES[name]
        return fam, mk_cfg(fam), mk_prob(fam)
    assert fam.example is not None, \
        f"{name}: no FIXTURES override and no example() to fall back on"
    cfg, prob = fam.example()
    return fam, cfg, prob


def test_fixture_overrides_match_registered_families():
    assert set(FIXTURES) <= set(ALL_FAMILIES), \
        "FIXTURES names a family that is not registered"


@pytest.mark.parametrize("name", ALL_FAMILIES)
class TestRoundTrip:
    def test_known_good_config_verifies(self, name):
        fam, cfg, prob = _fixture(name)
        prog = fam.build_program(cfg, prob)
        assert isinstance(prog, dsl.TileProgram)
        assert any(type(op).__name__.startswith("Assert")
                   for op in prog.ops), "family declares no invariants"
        res = fam.verify(cfg, prob)
        assert res.hard_ok, res.render()

    def test_every_injectable_bug_is_caught(self, name):
        fam, cfg, prob = _fixture(name)
        menu = fam.bugs_for(cfg, prob)
        assert set(menu) <= set(fam.injectable_bugs)
        assert menu, "fixture exposes no injectable bugs"
        for bug in menu:
            res = fam.verify(cfg, prob, inject_bug=bug)
            assert not res.hard_ok, \
                f"{name}: injected bug {bug!r} slipped through"

    def test_config_dispatch_and_dataclasses(self, name):
        fam, cfg, prob = _fixture(name)
        assert family_for_config(cfg) is fam
        assert dataclasses.is_dataclass(cfg) and dataclasses.is_dataclass(
            prob)
        # frozen, hashable configs are what make engine memo keys sound
        first_field = dataclasses.fields(cfg)[0].name
        with pytest.raises(dataclasses.FrozenInstanceError):
            setattr(cfg, first_field, 0)
        assert hash(cfg) is not None and hash(prob) is not None

    def test_cost_and_skills_hooks(self, name):
        fam, cfg, prob = _fixture(name)
        est = fam.cost(cfg, prob)
        assert est.time_s > 0 and est.flops > 0
        assert fam.skills, "family registers no skills"
        for skill in fam.skills:
            assert name in skill.families
            for label, new_cfg in skill.contexts(cfg, prob):
                assert isinstance(new_cfg, fam.config_cls), \
                    f"{skill.name} context {label} left the config space"

    def test_engine_feedback_is_stage_attributed(self, name):
        """Every caught bug yields structured Feedback whose stage is one
        of the engine's pipeline stages, with a repair hint."""
        from repro.core.verify_engine import VerificationEngine
        fam, cfg, prob = _fixture(name)
        eng = VerificationEngine()
        for bug in fam.bugs_for(cfg, prob):
            res = eng.verify(name, cfg, prob, inject_bug=bug)
            assert not res.hard_ok
            assert res.violations, f"{name}:{bug} produced no feedback"
            for f in res.violations:
                assert f.stage in ("structural", "build", "analysis",
                                   "solver")
                assert f.assertion_id and f.repair_hint

    def test_bug_signatures_are_ground_truth(self, name):
        """Every injectable bug declares a BugSignature, and injecting
        the bug actually produces a violation the signature matches at
        *exact* specificity (on the bug-friendly fixture and on the
        production example) — the property targeted repair rests on."""
        from repro.core.families import MATCH_EXACT
        from repro.core.verify_engine import VerificationEngine
        fam = get_family(name)
        sigs = {s.bug: s for s in fam.bug_signatures}
        assert set(sigs) == set(fam.injectable_bugs), \
            f"{name}: fault menu and signature map disagree"
        eng = VerificationEngine()
        fixtures = [_fixture(name)[1:]]
        if fam.example is not None:
            fixtures.append(fam.example())
        for cfg, prob in fixtures:
            for bug in fam.bugs_for(cfg, prob):
                res = eng.verify(name, cfg, prob, inject_bug=bug)
                best = max((sigs[bug].specificity(f.stage, f.assertion_id)
                            for f in res.violations), default=0)
                assert best == MATCH_EXACT, \
                    (f"{name}:{bug} signature missed its own feedback: "
                     f"{[(f.stage, f.assertion_id) for f in res.violations]}")

    def test_example_is_tunable(self, name):
        """examples/argus_optimize.py tunes every family's example() —
        it must verify clean and enumerate at least one skill context."""
        fam = get_family(name)
        if fam.example is None:
            pytest.skip("family has no production example")
        cfg, prob = fam.example()
        assert isinstance(cfg, fam.config_cls)
        assert isinstance(prob, fam.problem_cls)
        res = fam.verify(cfg, prob)
        assert res.hard_ok, res.render()
        contexts = [c for s in fam.skills for c in s.contexts(cfg, prob)]
        assert contexts, "example exposes no tuning moves"


@pytest.mark.parametrize("name", ALL_FAMILIES)
class TestSoLBound:
    """The analytic speed-of-light hook (``KernelFamily.sol_bound``):
    a config-independent roofline floor — ideal flops at peak MXU rate
    vs minimal one-pass HBM traffic — that the fleet tuner's ``--sol``
    early stop compares verified estimates against.  A bound that ever
    exceeded the cost hook would stop jobs above the floor, so the
    dominance property below is load-bearing, not cosmetic."""

    @staticmethod
    def _probs(fam):
        _cfg, prob = fam.example()
        probs = [prob]
        if fam.sweep_problems is not None:
            probs += fam.sweep_problems()
        return probs

    def test_bound_positive_and_finite(self, name):
        fam = get_family(name)
        assert fam.sol_bound is not None, \
            f"{name}: registered without a sol_bound hook"
        for prob in self._probs(fam):
            est = fam.sol_bound(prob)
            assert math.isfinite(est.compute_s) \
                and math.isfinite(est.memory_s), (name, prob)
            assert est.compute_s > 0 and est.memory_s > 0, (name, prob)
            assert est.flops > 0 and est.hbm_bytes > 0, (name, prob)
            assert est.time_s == max(est.compute_s, est.memory_s)

    def test_bound_never_exceeds_cost_hook(self, name):
        fam = get_family(name)
        for cfg in (fam.config_cls(), fam.example()[0]):
            for prob in self._probs(fam):
                sol = fam.sol_bound(prob).time_s
                cost = fam.cost(cfg, prob).time_s
                assert sol <= cost * (1 + 1e-9), \
                    (f"{name}: sol bound {sol:.3e}s above the cost "
                     f"hook's {cost:.3e}s for {cfg} on {prob}")


def test_registry_is_complete_and_consistent():
    fams = all_families()
    assert len(fams) >= 8
    for fam in fams:
        assert get_family(fam.name) is fam
        assert fam.build_program is not None
        assert fam.structural is not None and fam.cost is not None


def test_unknown_family_raises():
    with pytest.raises(KeyError):
        get_family("conv3d")
