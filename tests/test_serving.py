"""Paged serving: page-allocator properties (hypothesis), KV-pool
gather/scatter correctness, paged-vs-dense token identity (incl. under
pool-pressure preemption), the retirement-boundary regression, and the
fig_serving byte-identical-report determinism gate."""
import json
import sys
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.serve import (PagedServingEngine, PageAllocator, PoolExhausted,
                         Request, ServingEngine)
from repro.serve.metrics import ServingMetrics
from repro.serve.pool import KVPool, NULL_PAGE, pages_needed
from repro.serve.trace import bursty_trace, percentile, poisson_trace

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# Page allocator: property tests (pure bookkeeping, no jax)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # pragma: no cover - dev-only dependency
    st = None


def _drive(alloc: PageAllocator, ops, running=frozenset()):
    """Apply an op stream, swallowing expected PoolExhausted; the
    allocator's structural invariants must hold after every op."""
    for kind, seq, n in ops:
        try:
            if kind == "alloc":
                alloc.alloc(seq, n)
            elif kind == "ensure":
                alloc.ensure(seq, n * alloc.page_size)
            elif kind == "free":
                alloc.free_seq(seq)
            elif kind == "touch":
                alloc.touch(seq)
            elif kind == "evict":
                victim = alloc.lru_victim(protected=running)
                if victim is not None:
                    assert victim not in running
                    alloc.free_seq(victim)
        except PoolExhausted:
            pass
        alloc.check()


_KINDS = ("alloc", "ensure", "free", "evict", "touch")

if st is not None:
    # op stream over a small pool: (kind, seq, amount)
    _OPS = st.lists(
        st.tuples(st.sampled_from(_KINDS), st.integers(0, 5),
                  st.integers(1, 6)),
        max_size=60)

    class TestPageAllocatorProperties:
        @settings(max_examples=200, deadline=None)
        @given(ops=_OPS, n_pages=st.integers(2, 24))
        def test_no_page_mapped_twice_and_freelist_conserved(self, ops,
                                                             n_pages):
            """After any op sequence: every physical page is mapped to
            at most one sequence, the null page is never mapped, and
            free + mapped always partitions the usable pool."""
            _drive(PageAllocator(n_pages, page_size=4), ops)

        @settings(max_examples=200, deadline=None)
        @given(ops=_OPS, running=st.sets(st.integers(0, 5), max_size=4))
        def test_eviction_never_reclaims_running_sequence(self, ops,
                                                          running):
            """lru_victim(protected=running) never names a running
            sequence, no matter the interleaving of allocs, frees and
            evictions."""
            _drive(PageAllocator(9, page_size=4), ops,
                   running=frozenset(running))

        @settings(max_examples=100, deadline=None)
        @given(tok=st.integers(1, 64),
               ps=st.sampled_from([1, 2, 4, 8, 16]))
        def test_ensure_allocates_exactly_the_ceiling(self, tok, ps):
            a = PageAllocator(80, page_size=ps)
            a.ensure(0, tok)
            assert len(a.tables[0]) == pages_needed(tok, ps)
            a.ensure(0, tok)                    # idempotent
            assert len(a.tables[0]) == pages_needed(tok, ps)
else:
    class TestPageAllocatorProperties:
        @pytest.mark.skip(reason="hypothesis not installed — pip "
                          "install -r requirements-dev.txt")
        def test_hypothesis_properties(self):
            pass


class TestPageAllocator:
    def test_seeded_fuzz_conserves_pool_and_respects_protection(self):
        """Hypothesis-free twin of the property tests: a seeded random
        op stream (always runs, even without the dev deps) must keep
        every allocator invariant after each op and never evict a
        protected sequence."""
        rng = np.random.default_rng(0)
        for trial in range(40):
            n_pages = int(rng.integers(2, 25))
            running = frozenset(
                int(x) for x in rng.integers(0, 6, size=3))
            ops = [(_KINDS[int(rng.integers(len(_KINDS)))],
                    int(rng.integers(0, 6)), int(rng.integers(1, 7)))
                   for _ in range(60)]
            _drive(PageAllocator(n_pages, page_size=4), ops,
                   running=running)

    def test_ensure_allocates_exactly_the_ceiling(self):
        for ps in (1, 2, 4, 8, 16):
            for tok in (1, 3, ps, ps + 1, 4 * ps, 63):
                a = PageAllocator(80, page_size=ps)
                a.ensure(0, tok)
                assert len(a.tables[0]) == pages_needed(tok, ps)
                a.ensure(0, tok)                # idempotent
                assert len(a.tables[0]) == pages_needed(tok, ps)

    def test_alloc_is_deterministic_lowest_first(self):
        a = PageAllocator(6, page_size=4)
        assert a.alloc(0, 2) == [1, 2]
        assert a.alloc(1, 2) == [3, 4]
        a.free_seq(0)
        assert a.alloc(2, 3) == [1, 2, 5]

    def test_exhaustion_raises_and_protected_eviction_fails(self):
        a = PageAllocator(4, page_size=4)
        a.alloc(0, 3)
        with pytest.raises(PoolExhausted):
            a.alloc(1, 1)
        with pytest.raises(PoolExhausted):
            a.evict(protected=frozenset([0]))
        victim, pages = a.evict(protected=frozenset())
        assert victim == 0 and len(pages) == 3 and a.free_pages == 3


# ---------------------------------------------------------------------------
# KV pool storage: gather/scatter against a dense mirror
# ---------------------------------------------------------------------------

def _reduced_model():
    from repro import configs
    from repro.models import build
    return build(configs.get_reduced("qwen3-1.7b"))


class TestKVPool:
    def test_gather_matches_dense_mirror_and_null_page_stays_zero(self):
        model = _reduced_model()
        PS, P, B, NP = 4, 9, 2, 4
        pool = KVPool(model, P, PS)
        alloc = PageAllocator(P, PS)
        dense = model.init_cache(B, NP * PS)
        axes = model.cache_axes()

        rng = np.random.default_rng(0)
        writes = []   # (row, pos)
        for row, n_tok in ((0, 7), (1, 10)):
            alloc.ensure(row, n_tok)
            writes += [(row, p) for p in range(n_tok)]
        rows = np.array([w[0] for w in writes], np.int32)
        pos = np.array([w[1] for w in writes], np.int32)
        phys = np.array(
            [alloc.tables[r][p // PS] for r, p in writes], np.int32)
        offs = np.array([p % PS for _, p in writes], np.int32)

        # random per-(row,pos) values written into a dense view mirror
        def fill(leaf, ax):
            b, s = ax.index("batch"), ax.index("kv_seq")
            lm = np.array(jnp.moveaxis(leaf, (b, s), (0, 1)))
            for r, p in writes:
                lm[r, p] = rng.normal(size=lm.shape[2:])
            return jnp.moveaxis(jnp.asarray(lm, leaf.dtype), (0, 1),
                                (b, s))
        is_ax = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        dense = jax.tree.map(lambda ax, l: fill(l, ax), axes, dense,
                             is_leaf=is_ax)

        pool.scatter(dense, rows, pos, phys, offs)
        tables = np.stack([alloc.table_row(r, NP) for r in range(B)])
        view = pool.gather(jnp.asarray(tables))
        for got, want in zip(jax.tree.leaves(view),
                             jax.tree.leaves(dense)):
            np.testing.assert_array_equal(np.array(got), np.array(want))

        # the null page backs unallocated slots and must stay all-zero
        for leaf, ax in zip(jax.tree.leaves(pool.storage),
                            jax.tree.leaves(axes, is_leaf=is_ax)):
            null = jnp.take(leaf, NULL_PAGE, axis=ax.index("batch"))
            assert not np.array(null).any()

    def test_rejects_unpageable_models(self):
        class Fake:
            def cache_axes(self):
                return {"h": ("batch", "mlp")}

            def cache_shape(self, b, s):
                return {"h": jax.ShapeDtypeStruct((b, 8), jnp.float32)}
        with pytest.raises(ValueError, match="cannot be paged"):
            KVPool(Fake(), 4, 4)


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    model = _reduced_model()
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _submit_all(eng, reqs):
    for rid, (p, m) in enumerate(reqs):
        eng.submit(Request(rid, list(p), max_new_tokens=m))
    return {r.rid: r.output for r in eng.run()}


def _mixed_requests(seed=3, n=8, vocab=256):
    rng = np.random.default_rng(seed)
    return [(rng.integers(2, vocab, size=int(rng.integers(6, 28))).tolist(),
             int(rng.integers(4, 12))) for _ in range(n)]


class TestPagedEngine:
    def test_paged_matches_dense_token_for_token(self, served):
        model, params = served
        reqs = _mixed_requests()
        dense = _submit_all(ServingEngine(model, params, n_slots=4,
                                          max_len=64, eos_id=-1), reqs)
        paged = _submit_all(
            PagedServingEngine(model, params, pool_pages=40, page_size=8,
                               max_batch=4, max_len=64, prefill_chunk=8,
                               eos_id=-1), reqs)
        assert paged == dense

    def test_chunk_size_does_not_change_tokens(self, served):
        model, params = served
        reqs = _mixed_requests(seed=5, n=4)
        outs = [_submit_all(
            PagedServingEngine(model, params, pool_pages=40, page_size=8,
                               max_batch=2, max_len=64, prefill_chunk=c,
                               eos_id=-1), reqs)
            for c in (1, 4, 64)]
        assert outs[0] == outs[1] == outs[2]

    def test_preemption_resume_preserves_tokens(self, served):
        """A pool sized far below the working set forces recompute-style
        preemption; greedy decode must still produce the unpressured
        token streams, and the engine must report the evictions."""
        model, params = served
        reqs = _mixed_requests()
        roomy = _submit_all(
            PagedServingEngine(model, params, pool_pages=40, page_size=8,
                               max_batch=4, max_len=64, prefill_chunk=8,
                               eos_id=-1), reqs)
        tight = PagedServingEngine(model, params, pool_pages=9,
                                   page_size=8, max_batch=4, max_len=64,
                                   prefill_chunk=8, eos_id=-1)
        out = _submit_all(tight, reqs)
        assert out == roomy
        assert tight.metrics.counters["preempted"] > 0

    def test_admission_is_headroom_driven(self, served):
        """With a near-empty pool the queue waits even though decode
        rows are free; pages freed by retirement admit the next
        request."""
        model, params = served
        eng = PagedServingEngine(model, params, pool_pages=5, page_size=8,
                                 max_batch=4, max_len=32,
                                 prefill_chunk=8, eos_id=-1)
        eng.submit(Request(0, list(range(2, 20)), max_new_tokens=4))
        eng.submit(Request(1, list(range(2, 20)), max_new_tokens=4))
        eng.step()
        # 18-token prompt + 1 -> 3 pages of 4 usable: no room for req 1
        assert len(eng.active) == 1 and len(eng.queue) == 1
        done = eng.run()
        assert sorted(r.rid for r in done) == [0, 1]
        assert all(len(r.output) == 4 for r in done)

    def test_oversized_request_rejected_not_wedged(self, served):
        model, params = served
        eng = PagedServingEngine(model, params, pool_pages=3, page_size=8,
                                 max_batch=2, max_len=64,
                                 prefill_chunk=8, eos_id=-1)
        eng.submit(Request(0, list(range(2, 40)), max_new_tokens=4))
        eng.submit(Request(1, [2, 3, 4], max_new_tokens=3))
        done = eng.run()
        by = {r.rid: r for r in done}
        assert by[0].error and by[0].done
        assert by[1].error is None and len(by[1].output) == 3

    def test_requires_page_aligned_max_len(self, served):
        model, params = served
        with pytest.raises(ValueError, match="multiple of"):
            PagedServingEngine(model, params, pool_pages=8, page_size=8,
                               max_len=60)

    def test_block_table_oob_is_rejected(self, served):
        from repro.kernels.paged_attention.ops import (InvariantViolation,
                                                       validate_block_tables)
        model, _ = served
        bad = np.array([[0, 7]], np.int32)
        with pytest.raises(InvariantViolation, match="outside"):
            validate_block_tables(bad, model=model, page_size=8,
                                  pool_pages=4)
        cfg = validate_block_tables(np.array([[0, 1]], np.int32),
                                    model=model, page_size=8,
                                    pool_pages=4)
        assert cfg is not None

    def test_mapped_length_consistency(self, served):
        """Boundary-page regression: a row whose logical length crosses
        into page k while page k was never mapped must be rejected — as
        must over-mapping and a mapped page after a null hole; the exact
        page boundary passes."""
        from repro.kernels.paged_attention.ops import (InvariantViolation,
                                                       validate_block_tables)
        model, _ = served
        kw = dict(model=model, page_size=8, pool_pages=8)
        ok = np.array([[1, 2, 0, 0], [3, 0, 0, 0]], np.int32)
        # exact boundary: 16 tokens = exactly 2 pages; 8 = exactly 1
        assert validate_block_tables(
            ok, lengths=np.array([16, 8]), **kw) is not None
        # length 17 crosses into page 2 of row 0, which is unmapped
        with pytest.raises(InvariantViolation, match="needs 3"):
            validate_block_tables(ok, lengths=np.array([17, 8]), **kw)
        # row 1 holds a page its 0-length doesn't need
        with pytest.raises(InvariantViolation, match="row 1 maps 1"):
            validate_block_tables(ok, lengths=np.array([16, 0]), **kw)
        # a mapped page after a null hole is never a valid prefix
        holey = np.array([[1, 0, 2, 0]], np.int32)
        with pytest.raises(InvariantViolation, match="null hole"):
            validate_block_tables(holey, lengths=np.array([16]), **kw)
        # lengths shape must match the table
        with pytest.raises(InvariantViolation, match="shape"):
            validate_block_tables(ok, lengths=np.array([16]), **kw)

    def test_inactive_rows_validate_with_zero_length(self, served):
        from repro.kernels.paged_attention.ops import validate_block_tables
        model, _ = served
        t = np.array([[1, 2], [0, 0]], np.int32)
        assert validate_block_tables(
            t, model=model, page_size=8, pool_pages=8,
            lengths=np.array([9, 0])) is not None


class TestKernelDecodePath:
    """decode_path="kernel": the length-masked paged-attention kernel
    replaces the per-tick decode gather — token-identical to the gather
    path (itself the dense engine's twin), zero dense-view bytes."""

    def test_rejects_unknown_decode_path(self, served):
        model, params = served
        with pytest.raises(ValueError, match="decode_path"):
            PagedServingEngine(model, params, pool_pages=8, page_size=8,
                               max_len=32, decode_path="oracle")

    def test_kernel_matches_gather_token_for_token(self, served):
        model, params = served
        reqs = _mixed_requests(seed=11, n=4)
        outs, engs = {}, {}
        for path in ("gather", "kernel"):
            eng = PagedServingEngine(model, params, pool_pages=40,
                                     page_size=8, max_batch=4, max_len=64,
                                     prefill_chunk=8, eos_id=-1,
                                     decode_path=path)
            outs[path] = _submit_all(eng, reqs)
            engs[path] = eng
        assert outs["kernel"] == outs["gather"]
        kc = engs["kernel"].metrics.counters
        gc = engs["gather"].metrics.counters
        # kernel path: every decode tick ran the kernel, none gathered
        assert kc["gather_bytes"] == 0
        assert kc["kernel_decode_ticks"] > 0
        # gather path: the inverse
        assert gc["kernel_decode_ticks"] == 0
        assert gc["gather_bytes"] > 0

    def test_kernel_path_survives_preemption(self, served):
        model, params = served
        reqs = _mixed_requests(seed=11, n=4)
        roomy = _submit_all(
            PagedServingEngine(model, params, pool_pages=40, page_size=8,
                               max_batch=4, max_len=64, prefill_chunk=8,
                               eos_id=-1, decode_path="kernel"), reqs)
        tight = PagedServingEngine(model, params, pool_pages=9,
                                   page_size=8, max_batch=4, max_len=64,
                                   prefill_chunk=8, eos_id=-1,
                                   decode_path="kernel")
        assert _submit_all(tight, reqs) == roomy
        assert tight.metrics.counters["preempted"] > 0
        assert tight.metrics.counters["gather_bytes"] == 0


class TestRetirementBoundary:
    """Regression for the `pos >= max_len - 1` off-by-one: a sequence
    admitted at pos == max_len - 2 still owns the final writable cache
    position, so it decodes twice (3 tokens incl. the prefill token),
    not once."""

    def test_dense_uses_final_writable_position(self, served):
        model, params = served
        ml = 32
        eng = ServingEngine(model, params, n_slots=1, max_len=ml,
                            eos_id=-1)
        eng.submit(Request(0, list(range(2, 2 + ml - 2)),
                           max_new_tokens=10))
        (done,) = eng.run()
        assert len(done.output) == 3, \
            f"expected 3 tokens (prefill + 2 decode ticks), got " \
            f"{len(done.output)} — retirement boundary regressed"

    def test_paged_matches_dense_at_the_boundary(self, served):
        model, params = served
        ml = 32
        prompt = list(range(2, 2 + ml - 2))
        dense = ServingEngine(model, params, n_slots=1, max_len=ml,
                              eos_id=-1)
        dense.submit(Request(0, prompt, max_new_tokens=10))
        paged = PagedServingEngine(model, params, pool_pages=10,
                                   page_size=8, max_batch=1, max_len=ml,
                                   prefill_chunk=8, eos_id=-1)
        paged.submit(Request(0, prompt, max_new_tokens=10))
        assert dense.run()[0].output == paged.run()[0].output


# ---------------------------------------------------------------------------
# Metrics snapshot versioning (schema v3 with v2 back-compat)
# ---------------------------------------------------------------------------

class TestMetricsSchema:
    def _v3_snapshot(self):
        m = ServingMetrics(16, "paged")
        m.record_tick(queue_depth=1, active=2, occupancy=9,
                      decode_tokens=2, step_time_us=55)
        m.record_latency("ttft", 4)
        m.record_latency("tpot", 1)
        m.record_latency("queue_wait", 0)
        return m.snapshot()

    def test_v2_snapshot_loads_with_empty_latency(self):
        """A pre-latency (schema 2) snapshot still loads — latency
        defaults to empty histograms, the v4 prefill counters to 0 —
        and re-snapshots at the current version."""
        from repro.serve.metrics import SCHEMA_VERSION
        snap = self._v3_snapshot()
        v2 = {k: v for k, v in snap.items() if k != "latency"}
        v2["schema"] = 2
        v2["counters"] = {k: v for k, v in snap["counters"].items()
                          if k not in ("kernel_prefill_ticks",
                                       "prefill_gather_bytes")}
        m = ServingMetrics.from_snapshot(v2)
        assert m.counters == snap["counters"]
        assert all(h.count == 0 for h in m.latency.values())
        rt = m.snapshot()
        assert rt["schema"] == SCHEMA_VERSION
        assert all(d == {"scheme": "log2", "counts": {}, "sum": 0}
                   for d in rt["latency"].values())

    def test_v3_snapshot_loads_without_prefill_counters(self):
        """A schema-3 snapshot predates the prefill-path counters: they
        are optional on load (default 0) but a v3 snapshot carrying a
        key outside its schema is still rejected."""
        snap = self._v3_snapshot()
        v3 = dict(snap, schema=3)
        v3["counters"] = {k: v for k, v in snap["counters"].items()
                          if k not in ("kernel_prefill_ticks",
                                       "prefill_gather_bytes")}
        m = ServingMetrics.from_snapshot(v3)
        assert m.counters["kernel_prefill_ticks"] == 0
        assert m.counters["prefill_gather_bytes"] == 0
        bad = dict(v3)
        bad["counters"] = dict(v3["counters"], bogus=1)
        with pytest.raises(ValueError, match="counters keys"):
            ServingMetrics.from_snapshot(bad)

    def test_unknown_versions_rejected_naming_the_version(self):
        snap = self._v3_snapshot()
        for bad in (1, 5, 99, None):
            with pytest.raises(ValueError, match=f"schema {bad!r}"):
                ServingMetrics.from_snapshot({**snap, "schema": bad})

    def test_v3_round_trips_latency_exactly(self):
        snap = self._v3_snapshot()
        assert ServingMetrics.from_snapshot(snap).snapshot() == snap

    def test_v3_with_wrong_latency_keys_rejected(self):
        snap = self._v3_snapshot()
        snap["latency"] = {"ttft": snap["latency"]["ttft"]}
        with pytest.raises(ValueError, match="latency keys"):
            ServingMetrics.from_snapshot(snap)


# ---------------------------------------------------------------------------
# Trace replay determinism (fig_serving byte-identity gate)
# ---------------------------------------------------------------------------

class TestTraces:
    def test_traces_are_seed_deterministic(self):
        a = poisson_trace(seed=7, n_requests=10, mean_gap=2.0)
        b = poisson_trace(seed=7, n_requests=10, mean_gap=2.0)
        assert a == b
        c = bursty_trace(seed=7, n_bursts=3, burst_size=4, burst_gap=10)
        d = bursty_trace(seed=7, n_bursts=3, burst_size=4, burst_gap=10)
        assert c == d
        assert [e.tick for e in c] == sorted(e.tick for e in c)

    def test_percentile_is_nearest_rank(self):
        v = list(range(1, 101))
        assert percentile(v, 50) == 50
        assert percentile(v, 99) == 99
        assert percentile([], 50) == 0
        assert percentile([5], 99) == 5

    @pytest.mark.slow
    def test_fig_serving_report_is_byte_identical(self, served, tmp_path):
        """Replaying the same seeded arrival trace twice yields
        byte-identical report JSON — the tuner-journal byte-identity
        discipline applied to the serving benchmark."""
        sys.path.insert(0, str(REPO / "benchmarks"))
        try:
            import fig_serving
        finally:
            sys.path.pop(0)
        argv = ["--requests", "8", "--max-len", "32", "--page-size", "8",
                "--pool-pages", "13", "--prefill-chunk", "8", "--smoke"]
        f1, f2 = tmp_path / "a.json", tmp_path / "b.json"
        fig_serving.main(argv + ["--out", str(f1)])
        fig_serving.main(argv + ["--out", str(f2)])
        assert f1.read_bytes() == f2.read_bytes()
        rep = json.loads(f1.read_text())
        assert rep["schema"] == 4
        assert rep["traces"]["poisson"]["token_identical"]
        assert rep["traces"]["bursty"]["token_identical"]
        pct = rep["traces"]["poisson"]["paged"]["percentiles"]
        assert set(pct) == {"queue_wait", "ttft", "tpot", "step_time"}
        assert all(s["count"] > 0 for s in pct.values())
