"""fslock under real contention: concurrent ``merge_save`` writers must
union their entries (no lost updates), and a stale ``.lock`` sidecar
left behind by a killed process must not wedge the next taker."""
import json
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.core.fslock import locked, merge_save, replace_file

SRC = str(Path(__file__).resolve().parent.parent / "src")

# each subprocess hammers merge_save, adding its own keys one at a time —
# any read-merge-write race between the two would drop keys
_HAMMER = """
import sys
sys.path.insert(0, sys.argv[4])
from repro.core.fslock import merge_save
wid, rounds, path = sys.argv[1], int(sys.argv[2]), sys.argv[3]
for i in range(rounds):
    def merge(disk):
        d = dict(disk) if isinstance(disk, dict) else {}
        d[f"{wid}:{i}"] = i
        return d
    merge_save(path, merge)
"""


class TestMergeSave:
    def test_merges_over_disk_and_returns_document(self, tmp_path):
        path = tmp_path / "cache.json"
        path.write_text(json.dumps({"a": 1}))
        out = merge_save(path, lambda disk: {**disk, "b": 2})
        assert out == {"a": 1, "b": 2}
        assert json.loads(path.read_text()) == {"a": 1, "b": 2}

    def test_corrupt_or_missing_file_reads_as_none(self, tmp_path):
        path = tmp_path / "cache.json"
        assert merge_save(path, lambda disk: {"fresh": disk is None}) \
            == {"fresh": True}
        path.write_text("{not json")
        assert merge_save(path, lambda disk: {"fresh": disk is None}) \
            == {"fresh": True}

    @pytest.mark.multiproc
    def test_two_processes_hammering_one_file_lose_no_updates(
            self, tmp_path):
        path = tmp_path / "cache.json"
        rounds = 40
        procs = [subprocess.Popen(
            [sys.executable, "-c", _HAMMER, wid, str(rounds), str(path),
             SRC]) for wid in ("a", "b")]
        for p in procs:
            assert p.wait(timeout=120) == 0
        data = json.loads(path.read_text())
        missing = [f"{w}:{i}" for w in ("a", "b") for i in range(rounds)
                   if f"{w}:{i}" not in data]
        assert not missing, f"lost updates under contention: {missing}"

    def test_stale_lock_sidecar_does_not_deadlock(self, tmp_path):
        """A ``.lock`` file left by a killed process holds no flock (the
        lock dies with its holder) — the next writer must just take it."""
        path = tmp_path / "cache.json"
        Path(str(path) + ".lock").write_text("stale pid 12345\n")
        done = threading.Event()

        def write():
            merge_save(path, lambda disk: {"survived": True})
            done.set()

        t = threading.Thread(target=write, daemon=True)
        t.start()
        assert done.wait(timeout=10), \
            "merge_save wedged on a stale .lock sidecar"
        assert json.loads(path.read_text()) == {"survived": True}

    def test_replace_file_is_whole_file_and_leaves_no_temp(self,
                                                           tmp_path):
        path = tmp_path / "cache.json"
        path.write_text("old")
        replace_file(path, "new")
        assert path.read_text() == "new"
        assert not list(tmp_path.glob("*.tmp")), \
            "replace_file must clean up its temp file"

    def test_locked_is_reentrant_across_processes_shared(self, tmp_path):
        """Two shared locks coexist (readers don't serialize)."""
        path = tmp_path / "cache.json"
        with locked(path, exclusive=False):
            with locked(path, exclusive=False):
                pass
