"""VerificationEngine: staged feedback, cache accounting, cached-vs-cold
verdict equivalence across mutated configs, and the ICRL-hillclimb
solver-discharge bound (the incremental-reverification claim)."""
import dataclasses

import pytest

from repro.core.families import get_family
from repro.core.verify_engine import (ConstraintCache, Feedback,
                                      VerificationEngine, default_engine)

GEMM = get_family("gemm")
PROB = GEMM.problem_cls(512, 512, 1024)


def _statuses(res):
    """(label, status) list for verdict-equivalence comparison."""
    if res.report is None:
        return res.build_error
    return [(label, r.status) for label, r in res.report.results]


class TestCacheAccounting:
    def test_result_memo_hits_on_repeat(self):
        eng = VerificationEngine()
        r1 = eng.verify("gemm", GEMM.config_cls(), PROB)
        r2 = eng.verify("gemm", GEMM.config_cls(), PROB)
        assert not r1.cached and r2.cached
        assert _statuses(r1) == _statuses(r2)
        s = eng.stats()
        assert s["verify_calls"] == 2 and s["result_hits"] == 1

    def test_constraint_cache_counts_hits_and_misses(self):
        eng = VerificationEngine()
        eng.verify("gemm", GEMM.config_cls(), PROB)
        s0 = eng.stats()
        assert s0["solver_discharges"] > 0
        assert s0["constraint_lookups"] == (s0["constraint_hits"]
                                            + s0["solver_discharges"])
        # a mutated config re-discharges only the changed constraints
        eng.verify("gemm", GEMM.config_cls(stagger_k=True), PROB)
        s1 = eng.stats()
        new_misses = s1["solver_discharges"] - s0["solver_discharges"]
        new_lookups = s1["constraint_lookups"] - s0["constraint_lookups"]
        assert 0 < new_misses < new_lookups, \
            "stagger_k flip should share most constraints with the base"

    def test_cache_disabled_never_hits(self):
        eng = VerificationEngine(use_cache=False)
        eng.verify("gemm", GEMM.config_cls(), PROB)
        eng.verify("gemm", GEMM.config_cls(), PROB)
        s = eng.stats()
        assert s["result_hits"] == 0 and s["constraint_hits"] == 0

    def test_default_engine_is_shared(self):
        assert default_engine() is default_engine()

    def test_result_memo_is_bounded(self):
        eng = VerificationEngine()
        eng.MAX_RESULTS = 4
        small = GEMM.problem_cls(256, 256, 256)
        for bm in (8, 16, 32, 64, 128, 256):
            eng.verify("gemm", GEMM.config_cls(bm=bm), small)
        assert len(eng._results) <= 4

    def test_cached_counterexample_restamped_to_callers_site(self):
        cache = ConstraintCache()
        from repro.core.solver import prove_zero
        from repro.core.tags import Var
        v = Var("v", 4)
        diff = (v + 1) - v - 1 + 1    # == 1, violated
        r1 = cache.discharge(("zero", (diff,)),
                             lambda: prove_zero([diff],
                                                program_point="site_a"),
                             program_point="site_a")
        r2 = cache.discharge(("zero", (diff,)), lambda: None,
                             program_point="site_b")
        assert cache.hits == 1
        assert r1.counterexample.program_point == "site_a"
        assert r2.counterexample.program_point == "site_b"
        assert r2.status == r1.status


class TestSharedEngineAccounting:
    def test_optimize_kernel_reports_per_run_deltas(self):
        from repro.core.harness import (KernelState, Planner, Selector,
                                        Validator, optimize_kernel)
        engine = VerificationEngine()
        prob = GEMM.problem_cls(2048, 2048, 2048, "bf16")

        def run(seed):
            st = KernelState("gemm", GEMM.config_cls(), prob).refresh()
            return optimize_kernel(
                st, planner=Planner(),
                selector=Selector(temperature=0.1, seed=seed),
                validator=Validator(engine=engine), iterations=4)

        r1, r2 = run(1), run(1)
        # same trajectory on a shared engine: run 2's verify-call delta
        # must not include run 1's totals
        assert r2.verify_stats["verify_calls"] == \
            r1.verify_stats["verify_calls"]


def test_knowledge_base_contexts_are_config_polymorphic():
    from repro.core.harness.knowledge import KNOWLEDGE_BASE
    retile = next(s for s in KNOWLEDGE_BASE if s.name == "retile")
    fa = get_family("flash_attention")
    fa_prob = fa.problem_cls(2, 8, 2, 2048, 2048, 128)
    steps = retile.contexts(fa.config_cls(), fa_prob)
    assert steps and all(isinstance(c, fa.config_cls) for _, c in steps)
    gemm_steps = retile.contexts(GEMM.config_cls(), PROB)
    assert gemm_steps and all(isinstance(c, GEMM.config_cls)
                              for _, c in gemm_steps)


class TestVerdictEquivalence:
    """Property: for every config reachable by one skill application from
    the family default, the warm (shared-cache) verdict equals a cold
    (fresh-engine) verdict — the cache changes cost, never answers."""

    @pytest.mark.parametrize("family,prob_args", [
        ("gemm", (512, 512, 1024)),
        ("flash_attention", (2, 8, 2, 2048, 2048, 128)),
        ("moe", (4096, 1024, 2048, 16, 2)),
    ])
    def test_cached_equals_cold_across_mutations(self, family, prob_args):
        fam = get_family(family)
        prob = fam.problem_cls(*prob_args)
        base = fam.config_cls()
        warm = VerificationEngine()
        variants = [("base", base)]
        for skill in fam.skills:
            variants += skill.contexts(base, prob)
        assert len(variants) > 3
        for label, cfg in variants:
            warm_res = warm.verify(family, cfg, prob)
            cold_res = VerificationEngine().verify(family, cfg, prob)
            assert _statuses(warm_res) == _statuses(cold_res), \
                f"{family}:{label} warm/cold verdicts diverge"
            assert warm_res.hard_ok == cold_res.hard_ok
        assert warm.stats()["constraint_hits"] > 0

    def test_cached_equals_cold_with_injected_bugs(self):
        warm = VerificationEngine()
        for bug in (None,) + GEMM.injectable_bugs:
            cfg = GEMM.config_cls(stagger_k=(bug == "stagger_mismatch"))
            warm_res = warm.verify("gemm", cfg, PROB, inject_bug=bug)
            cold_res = VerificationEngine().verify("gemm", cfg, PROB,
                                                   inject_bug=bug)
            assert _statuses(warm_res) == _statuses(cold_res)
            assert warm_res.hard_ok == (bug is None)


class TestStagedFeedback:
    def test_solver_violation_feedback_is_structured(self):
        eng = VerificationEngine()
        res = eng.verify("gemm", GEMM.config_cls(), PROB,
                         inject_bug="swap_b_index")
        bad = [f for f in res.violations if f.stage == "solver"]
        assert bad, "expected solver-stage feedback"
        f = bad[0]
        assert isinstance(f, Feedback)
        assert f.assertion_id and f.repair_hint
        assert f.counterexample is not None and f.counterexample.env

    def test_build_error_is_build_stage(self):
        eng = VerificationEngine()
        res = eng.verify("gemm", GEMM.config_cls(split_k=3), PROB)
        assert res.build_error is not None and not res.hard_ok
        assert any(f.stage == "build" for f in res.violations)

    def test_structural_issue_is_structural_stage(self):
        eng = VerificationEngine()
        res = eng.verify("gemm", GEMM.config_cls(bk=64), PROB)
        assert res.hard_ok and not res.ok     # warning, not violation
        assert any(f.stage == "structural" for f in res.violations)

    def test_lattice_violation_is_analysis_stage(self):
        eng = VerificationEngine()
        res = eng.verify("gemm", GEMM.config_cls(), PROB,
                         inject_bug="missing_init")
        assert not res.hard_ok
        assert any(f.stage == "analysis" for f in res.violations)


class TestConstraintPersistence:
    """ROADMAP "solver-cache persistence": proven verdicts round-trip to
    disk (stable, extent-qualified keys) so repeat tuning runs start
    warm; the persisted store never changes an answer."""

    def test_warm_start_round_trip(self, tmp_path):
        path = tmp_path / "constraint_cache.json"
        cold = VerificationEngine()
        r_cold = cold.verify("gemm", GEMM.config_cls(), PROB)
        n = cold.constraints.save(path)
        assert n > 0 and path.exists()

        warm_cache = ConstraintCache()
        assert warm_cache.load(path) == n
        warm = VerificationEngine(constraints=warm_cache)
        r_warm = warm.verify("gemm", GEMM.config_cls(), PROB)
        assert _statuses(r_warm) == _statuses(r_cold)
        s = warm.stats()
        assert s["persisted_hits"] > 0
        assert s["solver_discharges"] < \
            cold.stats()["solver_discharges"], \
            "warm start should skip previously proven discharges"

    def test_violations_are_not_persisted(self, tmp_path):
        path = tmp_path / "constraint_cache.json"
        eng = VerificationEngine()
        eng.verify("gemm", GEMM.config_cls(), PROB,
                   inject_bug="swap_b_index")
        eng.constraints.save(path)
        warm_cache = ConstraintCache()
        warm_cache.load(path)
        warm = VerificationEngine(constraints=warm_cache)
        res = warm.verify("gemm", GEMM.config_cls(), PROB,
                          inject_bug="swap_b_index")
        assert not res.hard_ok, \
            "a persisted cache must never flip a violation to a pass"

    def test_persisted_store_is_size_bounded(self, tmp_path):
        path = tmp_path / "constraint_cache.json"
        eng = VerificationEngine()
        for bm in (32, 64, 128, 256):
            eng.verify("gemm", GEMM.config_cls(bm=bm), PROB)
        cache = eng.constraints
        old_bound, ConstraintCache.MAX_PERSISTED = \
            ConstraintCache.MAX_PERSISTED, 5
        try:
            assert cache.save(path) <= 5
        finally:
            ConstraintCache.MAX_PERSISTED = old_bound

    def test_concurrent_saves_union_instead_of_clobber(self, tmp_path):
        """Two workers saving to one file must union their verdicts (the
        merge base is re-read inside the exclusive lock), not have the
        later save clobber the earlier one's entries."""
        path = tmp_path / "constraint_cache.json"
        qg = get_family("quant_gemm")
        qg_cfg, qg_prob = qg.example()

        worker_a = VerificationEngine()
        worker_a.verify("gemm", GEMM.config_cls(), PROB)
        worker_b = VerificationEngine()
        worker_b.verify("quant_gemm", qg_cfg, qg_prob)
        n_a = worker_a.constraints.save(path)
        n_b = worker_b.constraints.save(path)
        assert n_b > n_a, "B's save must keep A's on-disk entries"

        warm_cache = ConstraintCache()
        warm_cache.load(path)
        warm = VerificationEngine(constraints=warm_cache)
        warm.verify("gemm", GEMM.config_cls(), PROB)
        warm.verify("quant_gemm", qg_cfg, qg_prob)
        assert warm.stats()["solver_discharges"] == 0, \
            "the union must warm both workers' constraint sets"

    def test_corrupt_or_missing_file_starts_cold(self, tmp_path):
        cache = ConstraintCache()
        assert cache.load(tmp_path / "nope.json") == 0
        bad = tmp_path / "bad.json"
        bad.write_text("{not json")
        assert cache.load(bad) == 0

    def test_stable_keys_pin_variable_extents(self):
        from repro.core.tags import Var
        from repro.core.verify_engine import stable_constraint_key
        a = stable_constraint_key(("eq", (Var("v", 4) - 0,)))
        b = stable_constraint_key(("eq", (Var("v", 8) - 0,)))
        assert a != b, "same name, different domain => different key"


class TestHillclimbDischargeBound:
    def test_icrl_hillclimb_reuses_proofs(self):
        """Acceptance: a 10-step hillclimb on GEMM performs fewer solver
        discharges than assertion-count × verify-calls (the no-cache
        worst case)."""
        from repro.core.harness import (KernelState, Planner, Selector,
                                        Validator, optimize_kernel)
        engine = VerificationEngine()
        st = KernelState("gemm", GEMM.config_cls(),
                         GEMM.problem_cls(8192, 8192, 8192, "bf16"))
        st.refresh()
        res = optimize_kernel(st, planner=Planner(),
                              selector=Selector(temperature=0.1, seed=1),
                              validator=Validator(engine=engine),
                              iterations=10)
        prog = GEMM.build_program(GEMM.config_cls(),
                                  GEMM.problem_cls(8192, 8192, 8192,
                                                   "bf16"))
        n_assert = sum(1 for op in prog.ops
                       if type(op).__name__.startswith("Assert"))
        stats = res.verify_stats
        assert stats["verify_calls"] >= 10
        worst = n_assert * stats["verify_calls"]
        assert 0 < stats["solver_discharges"] < worst, stats
        assert stats["constraint_hits"] + stats["result_hits"] > 0
        # symbolic skeletons: the whole hillclimb pays for at most a
        # couple of full Python traces — every congruent config either
        # re-binds an interned skeleton or (via gemm's trace_fields
        # projection) skips the trace outright
        assert stats["full_builds"] <= 2, stats


class TestTraceFieldsProjection:
    """KernelFamily.trace_fields: configs differing only in
    trace-irrelevant knobs share one traced program — re-binding a
    congruent config skips the Python trace entirely (counted as
    ``trace_skips``), while the structural stage still sees the exact
    config."""

    GEMM = get_family("gemm")

    def test_precision_rebind_skips_the_trace(self):
        eng = VerificationEngine()
        prob = self.GEMM.problem_cls(2048, 2048, 2048, "bf16")
        r32 = eng.verify("gemm", self.GEMM.config_cls(), prob)
        rbf = eng.verify("gemm", self.GEMM.config_cls(precision="bf16"),
                         prob)
        s = eng.stats()
        assert s["full_builds"] == 1, s
        assert s["trace_skips"] == 1, s
        assert s["program_hits"] == 1, s
        assert r32.hard_ok and rbf.hard_ok
        # the analysis verdicts are identical; the results are still
        # memoized per exact config
        assert eng.verify("gemm", self.GEMM.config_cls(precision="bf16"),
                          prob).cached

    def test_trace_relevant_knobs_still_retrace(self):
        eng = VerificationEngine()
        prob = self.GEMM.problem_cls(2048, 2048, 2048, "bf16")
        eng.verify("gemm", self.GEMM.config_cls(bm=128), prob)
        eng.verify("gemm", self.GEMM.config_cls(bm=256), prob)
        s = eng.stats()
        assert s["trace_skips"] == 0, s
        assert s["full_builds"] + s["skeleton_rebinds"] == 2, s

    def test_structural_stage_reads_the_exact_config(self):
        """The projection must not leak into stage 1: a precision flip
        that changes the VMEM footprint still gets its own structural
        verdict even though the traced program is shared."""
        eng = VerificationEngine()
        prob = self.GEMM.problem_cls(4096, 4096, 4096, "bf16")
        # sits right on the VMEM boundary: the f32 accumulator scratch
        # overflows, the bf16 one fits
        cfg = self.GEMM.config_cls(bm=1024, bn=1024, bk=1280)
        small = eng.verify("gemm",
                           dataclasses.replace(cfg, precision="bf16"),
                           prob)
        big = eng.verify("gemm", cfg, prob)
        s = eng.stats()
        assert s["full_builds"] == 1 and s["trace_skips"] == 1, s
        assert small.ok and not small.structural
        assert not big.ok
        assert any(i.kind == "vmem" for i in big.structural), big.structural

    def test_flash_causal_block_skip_flip_skips_the_trace(self):
        """flash_attention's causal_block_skip only shifts the cost
        model — a flip shares the traced program via trace_fields."""
        fam = get_family("flash_attention")
        eng = VerificationEngine()
        prob = fam.problem_cls(2, 8, 1, 2048, 2048, 128, True, "bf16")
        on = eng.verify("flash_attention", fam.config_cls(), prob)
        off = eng.verify(
            "flash_attention",
            fam.config_cls(causal_block_skip=False), prob)
        s = eng.stats()
        assert s["trace_skips"] == 1 and s["program_hits"] == 1, s
        assert on.hard_ok and off.hard_ok

    def test_paged_block_pages_flip_retraces(self):
        """paged_attention's projection is the identity — every knob is
        trace-relevant, so a block_pages flip never skips the trace."""
        fam = get_family("paged_attention")
        eng = VerificationEngine()
        prob = fam.problem_cls(4, 8, 1, 1024, 64, 128, 128, "bf16")
        eng.verify("paged_attention", fam.config_cls(block_pages=1), prob)
        eng.verify("paged_attention", fam.config_cls(block_pages=2), prob)
        s = eng.stats()
        assert s["trace_skips"] == 0, s
        assert s["full_builds"] + s["skeleton_rebinds"] == 2, s

    def test_flash_sweep_trace_work_is_bounded(self):
        """Regression bound for the tuner's hot loop: sweeping block
        sizes x causal_block_skip pays one Python trace per block
        geometry, never per config — the skip flips all land in the
        trace memo."""
        fam = get_family("flash_attention")
        eng = VerificationEngine()
        prob = fam.problem_cls(2, 8, 1, 2048, 2048, 128, True, "bf16")
        for bq in (64, 128, 256):
            for skip in (True, False):
                eng.verify("flash_attention",
                           fam.config_cls(block_q=bq,
                                          causal_block_skip=skip), prob)
        s = eng.stats()
        assert s["full_builds"] <= 3, s
        assert s["trace_skips"] >= 3, s
