"""paged_attention family: block-table indirection invariants, the
pre-solver out-of-range catch, fault-menu gating, and the interpret-mode
kernel vs the dense-decode oracle."""
import numpy as np
import pytest

from repro.core.families import get_family
from repro.core.verify_engine import VerificationEngine

FAM = get_family("paged_attention")
CFG = FAM.config_cls(block_pages=2)
# 2 seqs × 8 GQA heads ÷ 2 kv heads, 1024 tokens in 128-token pages,
# 20-page pool (16 needed + slack)
PROB = FAM.problem_cls(2, 8, 2, 1024, 128, 20, 128)


class TestIndirectionInvariants:
    def test_good_config_proves_all_assertions(self):
        res = FAM.verify(CFG, PROB)
        assert res.hard_ok, res.render()

    def test_out_of_range_mapping_caught_before_the_solver(self):
        """The acceptance property: a stale/out-of-range page mapping is
        caught *structurally* (interval arithmetic at the analysis
        stage), before any solver search."""
        eng = VerificationEngine()
        res = eng.verify("paged_attention", CFG, PROB,
                         inject_bug="page_oob")
        assert not res.hard_ok
        assert res.violations
        for f in res.violations:
            assert f.stage == "analysis", \
                f"page_oob leaked to stage {f.stage}"
        assert any("out of range" in (f.counterexample.detail or "")
                   for f in res.violations if f.counterexample)

    def test_stale_v_table_yields_solver_counterexample(self):
        eng = VerificationEngine()
        res = eng.verify("paged_attention", CFG, PROB,
                         inject_bug="v_stale_table")
        assert not res.hard_ok
        bad = [f for f in res.violations if f.stage == "solver"
               and f.counterexample is not None]
        assert bad and bad[0].counterexample.env
        assert bad[0].repair_hint

    def test_page_skip_and_replay_hit_the_coverage_machinery(self):
        skip = FAM.verify(CFG, PROB, inject_bug="page_skip")
        assert not skip.hard_ok
        assert any("coverage" in label for label, r
                   in skip.report.violations)
        replay = FAM.verify(CFG, PROB, inject_bug="page_replay")
        assert not replay.hard_ok
        assert any("disjoint" in label for label, r
                   in replay.report.violations)

    def test_physical_position_bug_is_caught(self):
        res = FAM.verify(CFG, PROB, inject_bug="pos_from_physical")
        assert not res.hard_ok

    def test_fault_menu_gating(self):
        mha = FAM.problem_cls(2, 8, 8, 1024, 128, 20, 128)
        assert "wrong_kv_head" not in FAM.bugs_for(CFG, mha)
        single = FAM.config_cls(block_pages=1)
        assert "page_replay" not in FAM.bugs_for(single, PROB)
        # the hoisted-gate fault needs a second page in the block to leak
        assert "null_page_leak" not in FAM.bugs_for(single, PROB)
        assert "null_page_leak" in FAM.bugs_for(CFG, PROB)
        whole = FAM.config_cls(block_pages=8)   # 8 pages = whole range
        assert "page_skip" not in FAM.bugs_for(whole, PROB)

    @pytest.mark.parametrize("bug", ["mask_off_by_one", "null_page_leak"])
    def test_length_gate_faults_yield_solver_counterexamples(self, bug):
        """The two length-mask faults break the length-gate conformity
        assertion with a concrete counterexample at the solver stage —
        stage-attributed through the standard engine, like every other
        entry in the fault menu."""
        eng = VerificationEngine()
        res = eng.verify("paged_attention", CFG, PROB, inject_bug=bug)
        assert not res.hard_ok
        bad = [f for f in res.violations if f.stage == "solver"
               and f.counterexample is not None]
        assert bad, [f.assertion_id for f in res.violations]
        # a concrete witness: either a variable assignment or a
        # constant-difference disproof (hoisted gate: off by a whole page)
        ce = bad[0].counterexample
        assert ce.env or ce.detail, "no concrete witness"
        assert bad[0].repair_hint
        # only the length-gate conformity assertions fire — the page
        # indirection/coverage invariants stay proven
        assert all("assert_conform" in f.assertion_id
                   for f in res.violations), \
            [f.assertion_id for f in res.violations]

    def test_length_gate_fault_signatures_are_registry_exact(self):
        """Registry-parametrized ground truth for the new faults: the
        declared BugSignature matches the emitted feedback EXACTLY (its
        own assertion at its own stage), on the fixture shape and on the
        family example shape."""
        from repro.core.families.base import MATCH_EXACT
        eng = VerificationEngine()
        ex_cfg, ex_prob = FAM.example()
        for cfg, prob in ((CFG, PROB), (ex_cfg, ex_prob)):
            for bug in ("mask_off_by_one", "null_page_leak"):
                if bug not in FAM.bugs_for(cfg, prob):
                    continue
                sig = next(s for s in FAM.bug_signatures if s.bug == bug)
                res = eng.verify("paged_attention", cfg, prob,
                                 inject_bug=bug)
                assert any(
                    sig.specificity(f.stage, f.assertion_id) == MATCH_EXACT
                    for f in res.violations), (bug, cfg, prob)

    def test_structural_capacity_check(self):
        tiny_pool = FAM.problem_cls(2, 8, 2, 1024, 128, 8, 128)
        issues = FAM.structural(CFG, tiny_pool)
        assert any(s.kind == "capacity" for s in issues)

    def test_block_pages_must_tile_the_sequence(self):
        eng = VerificationEngine()
        res = eng.verify("paged_attention", FAM.config_cls(block_pages=3),
                         PROB)
        assert res.build_error is not None
        assert any(f.stage == "build" for f in res.violations)


class TestOracle:
    def test_gather_cache_flattens_through_the_table(self):
        import jax.numpy as jnp
        from repro.kernels.paged_attention import gather_cache
        rng = np.random.default_rng(0)
        pages = jnp.asarray(rng.normal(size=(6, 2, 4, 8)), jnp.float32)
        table = jnp.asarray([[4, 0, 2], [1, 5, 3]], jnp.int32)
        dense = gather_cache(pages, table)
        assert dense.shape == (2, 2, 12, 8)
        np.testing.assert_array_equal(
            np.asarray(dense[1, :, 4:8]), np.asarray(pages[5]))

    @pytest.mark.slow
    def test_interpret_mode_matches_dense_decode(self):
        assert FAM.reference_check(CFG, PROB)

    def test_ragged_lengths_match_the_masked_oracle(self):
        """Interpret-mode kernel vs the masked dense oracle across a
        ragged length vector: zero-length (inactive row), mid-page,
        exact page boundary, boundary+1, and the full span."""
        import jax.numpy as jnp
        from repro.kernels.paged_attention import (paged_decode_ref,
                                                   default_config)
        from repro.kernels.paged_attention.paged_attention import \
            paged_decode as kernel
        B, Hq, HK, NP, PS, D, P = 5, 4, 2, 4, 8, 16, 12
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(P, HK, PS, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(P, HK, PS, D)), jnp.float32)
        table = jnp.asarray(rng.integers(1, P, size=(B, NP)), jnp.int32)
        lengths = jnp.asarray([0, 5, PS * 2, PS * 2 + 1, NP * PS],
                              jnp.int32)
        got = kernel(q, kp, vp, table, lengths,
                     cfg=default_config(NP), interpret=True)
        want = paged_decode_ref(q, kp, vp, table, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        # the zero-length row reads nothing: exact zero output
        assert float(jnp.abs(got[0]).max()) == 0.0

    def test_masked_positions_never_reach_the_accumulator(self):
        """Poison every page the lengths say is unreadable (incl. the
        null page) with huge values — the kernel output must not move."""
        import jax.numpy as jnp
        from repro.kernels.paged_attention import default_config
        from repro.kernels.paged_attention.paged_attention import \
            paged_decode as kernel
        B, Hq, HK, NP, PS, D, P = 2, 2, 2, 4, 8, 16, 10
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(P, HK, PS, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(P, HK, PS, D)), jnp.float32)
        table = jnp.asarray([[1, 2, 0, 0], [3, 4, 5, 0]], jnp.int32)
        lengths = jnp.asarray([PS + 3, 3 * PS], jnp.int32)
        clean = kernel(q, kp, vp, table, lengths,
                       cfg=default_config(NP), interpret=True)
        # poison the null page, the unmapped tail, and row 0's dead
        # region beyond its length inside its own last mapped page
        kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
        for pg in (0, 6, 7, 8, 9):
            kp2[pg] = 1e6; vp2[pg] = 1e6
        kp2[2, :, 3:] = 1e6        # row 0's last page: offsets >= 3 are
        vp2[2, :, 3:] = 1e6        # at/beyond its length PS+3
        poisoned = kernel(q, jnp.asarray(kp2), jnp.asarray(vp2), table,
                          lengths, cfg=default_config(NP), interpret=True)
        np.testing.assert_array_equal(np.asarray(clean),
                                      np.asarray(poisoned))

    @pytest.mark.slow
    def test_validated_entry_rejects_bad_block_pages(self):
        import jax.numpy as jnp
        from repro.kernels.paged_attention import (InvariantViolation,
                                                   paged_decode)
        q = jnp.zeros((1, 2, 1, 128), jnp.float32)
        kp = jnp.zeros((6, 2, 128, 128), jnp.float32)
        table = jnp.zeros((1, 4), jnp.int32)
        with pytest.raises(InvariantViolation):
            paged_decode(q, kp, kp, table,
                         cfg=FAM.config_cls(block_pages=3),
                         interpret=True)
