"""paged_attention family: block-table indirection invariants, the
pre-solver out-of-range catch, fault-menu gating, and the interpret-mode
kernel vs the dense-decode oracle."""
import numpy as np
import pytest

from repro.core.families import get_family
from repro.core.verify_engine import VerificationEngine

FAM = get_family("paged_attention")
CFG = FAM.config_cls(block_pages=2)
# 2 seqs × 8 GQA heads ÷ 2 kv heads, 1024 tokens in 128-token pages,
# 20-page pool (16 needed + slack)
PROB = FAM.problem_cls(2, 8, 2, 1024, 128, 20, 128)


class TestIndirectionInvariants:
    def test_good_config_proves_all_assertions(self):
        res = FAM.verify(CFG, PROB)
        assert res.hard_ok, res.render()

    def test_out_of_range_mapping_caught_before_the_solver(self):
        """The acceptance property: a stale/out-of-range page mapping is
        caught *structurally* (interval arithmetic at the analysis
        stage), before any solver search."""
        eng = VerificationEngine()
        res = eng.verify("paged_attention", CFG, PROB,
                         inject_bug="page_oob")
        assert not res.hard_ok
        assert res.violations
        for f in res.violations:
            assert f.stage == "analysis", \
                f"page_oob leaked to stage {f.stage}"
        assert any("out of range" in (f.counterexample.detail or "")
                   for f in res.violations if f.counterexample)

    def test_stale_v_table_yields_solver_counterexample(self):
        eng = VerificationEngine()
        res = eng.verify("paged_attention", CFG, PROB,
                         inject_bug="v_stale_table")
        assert not res.hard_ok
        bad = [f for f in res.violations if f.stage == "solver"
               and f.counterexample is not None]
        assert bad and bad[0].counterexample.env
        assert bad[0].repair_hint

    def test_page_skip_and_replay_hit_the_coverage_machinery(self):
        skip = FAM.verify(CFG, PROB, inject_bug="page_skip")
        assert not skip.hard_ok
        assert any("coverage" in label for label, r
                   in skip.report.violations)
        replay = FAM.verify(CFG, PROB, inject_bug="page_replay")
        assert not replay.hard_ok
        assert any("disjoint" in label for label, r
                   in replay.report.violations)

    def test_physical_position_bug_is_caught(self):
        res = FAM.verify(CFG, PROB, inject_bug="pos_from_physical")
        assert not res.hard_ok

    def test_fault_menu_gating(self):
        mha = FAM.problem_cls(2, 8, 8, 1024, 128, 20, 128)
        assert "wrong_kv_head" not in FAM.bugs_for(CFG, mha)
        single = FAM.config_cls(block_pages=1)
        assert "page_replay" not in FAM.bugs_for(single, PROB)
        whole = FAM.config_cls(block_pages=8)   # 8 pages = whole range
        assert "page_skip" not in FAM.bugs_for(whole, PROB)

    def test_structural_capacity_check(self):
        tiny_pool = FAM.problem_cls(2, 8, 2, 1024, 128, 8, 128)
        issues = FAM.structural(CFG, tiny_pool)
        assert any(s.kind == "capacity" for s in issues)

    def test_block_pages_must_tile_the_sequence(self):
        eng = VerificationEngine()
        res = eng.verify("paged_attention", FAM.config_cls(block_pages=3),
                         PROB)
        assert res.build_error is not None
        assert any(f.stage == "build" for f in res.violations)


class TestOracle:
    def test_gather_cache_flattens_through_the_table(self):
        import jax.numpy as jnp
        from repro.kernels.paged_attention import gather_cache
        rng = np.random.default_rng(0)
        pages = jnp.asarray(rng.normal(size=(6, 2, 4, 8)), jnp.float32)
        table = jnp.asarray([[4, 0, 2], [1, 5, 3]], jnp.int32)
        dense = gather_cache(pages, table)
        assert dense.shape == (2, 2, 12, 8)
        np.testing.assert_array_equal(
            np.asarray(dense[1, :, 4:8]), np.asarray(pages[5]))

    @pytest.mark.slow
    def test_interpret_mode_matches_dense_decode(self):
        assert FAM.reference_check(CFG, PROB)

    @pytest.mark.slow
    def test_validated_entry_rejects_bad_block_pages(self):
        import jax.numpy as jnp
        from repro.kernels.paged_attention import (InvariantViolation,
                                                   paged_decode)
        q = jnp.zeros((1, 2, 1, 128), jnp.float32)
        kp = jnp.zeros((6, 2, 128, 128), jnp.float32)
        table = jnp.zeros((1, 4), jnp.int32)
        with pytest.raises(InvariantViolation):
            paged_decode(q, kp, kp, table,
                         cfg=FAM.config_cls(block_pages=3),
                         interpret=True)
