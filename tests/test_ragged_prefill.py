"""ragged_prefill family: cross-sequence leakage invariants, the
pre-solver offset-bound catch, fault-menu gating, the interpret-mode
kernel vs the masked dense oracle, and the poisoned-KV leakage canary
(foreign-sequence / padding KV slots full of sentinel garbage must
leave every other sequence's output bit-identical)."""
import numpy as np
import pytest

from repro.core.families import get_family
from repro.core.verify_engine import VerificationEngine

FAM = get_family("ragged_prefill")
CFG = FAM.config_cls(block_q=64, block_kv=64)
# 3 packed sequences in a 512-token buffer, GQA 8:2 heads
PROB = FAM.problem_cls(3, 512, 8, 2, 128)


class TestLeakageInvariants:
    def test_good_config_proves_all_assertions(self):
        res = FAM.verify(CFG, PROB)
        assert res.hard_ok, res.render()

    def test_offset_oob_caught_before_the_solver(self):
        """The acceptance property: a cu_seqlens table whose declared
        range escapes the packed buffer is caught *structurally*
        (interval arithmetic at the analysis stage), before any solver
        search."""
        eng = VerificationEngine()
        res = eng.verify("ragged_prefill", CFG, PROB, inject_bug="cu_oob")
        assert not res.hard_ok
        assert res.violations
        for f in res.violations:
            assert f.stage == "analysis", \
                f"cu_oob leaked to stage {f.stage}"
        assert all("assert_in_range(segment offset" in f.assertion_id
                   for f in res.violations)

    @pytest.mark.parametrize("bug", ["cross_seq_leak", "causal_off_by_one",
                                     "wrong_cu_base"])
    def test_leakage_gate_faults_yield_solver_counterexamples(self, bug):
        """The three leakage-mask faults break the gate conformity
        assertion with a concrete counterexample at the solver stage —
        a cross-boundary read, an off-by-one causal bound and a
        mis-based offset all surface as the same invariant class: the
        weight entering the accumulator does not carry the
        (seg_q, seg_k, pos_q, pos_k) quadruple its gate admitted."""
        eng = VerificationEngine()
        res = eng.verify("ragged_prefill", CFG, PROB, inject_bug=bug)
        assert not res.hard_ok
        bad = [f for f in res.violations if f.stage == "solver"
               and f.counterexample is not None]
        assert bad, [f.assertion_id for f in res.violations]
        ce = bad[0].counterexample
        assert ce.env or ce.detail, "no concrete witness"
        assert bad[0].repair_hint
        # only gate conformity fires — coverage/stability stay proven
        assert all("assert_conform" in f.assertion_id
                   for f in res.violations), \
            [f.assertion_id for f in res.violations]

    def test_segment_skip_and_replay_hit_the_coverage_machinery(self):
        skip = FAM.verify(CFG, PROB, inject_bug="segment_skip")
        assert not skip.hard_ok
        assert any("coverage" in label for label, r
                   in skip.report.violations)
        replay = FAM.verify(CFG, PROB, inject_bug="segment_replay")
        assert not replay.hard_ok
        assert any("disjoint" in label for label, r
                   in replay.report.violations)

    def test_tail_mask_and_carry_faults_are_caught(self):
        assert not FAM.verify(CFG, PROB,
                              inject_bug="mask_dropped_tail").hard_ok
        assert not FAM.verify(CFG, PROB,
                              inject_bug="acc_depends_kv").hard_ok

    def test_fault_menu_gating(self):
        mha = FAM.problem_cls(3, 512, 8, 8, 128)
        assert "wrong_kv_head" not in FAM.bugs_for(CFG, mha)
        assert "wrong_kv_head" in FAM.bugs_for(CFG, PROB)
        # one kv block == the whole packed range: nothing to skip/replay
        whole = FAM.config_cls(block_q=64, block_kv=512)
        menu = FAM.bugs_for(whole, PROB)
        assert "segment_skip" not in menu
        assert "segment_replay" not in menu

    def test_structural_capacity_and_tiling_checks(self):
        overfull = FAM.problem_cls(600, 512, 8, 2, 128)
        assert any(s.kind == "capacity"
                   for s in FAM.structural(CFG, overfull))
        ragged = FAM.problem_cls(3, 500, 8, 2, 128)
        assert any(s.kind == "masking"
                   for s in FAM.structural(CFG, ragged))

    def test_blocks_must_tile_the_packed_buffer(self):
        eng = VerificationEngine()
        res = eng.verify("ragged_prefill",
                         FAM.config_cls(block_q=96, block_kv=64), PROB)
        assert res.build_error is not None
        assert any(f.stage == "build" for f in res.violations)


def _packed_case(lens, total, H=4, HK=2, D=32, seed=0, dtype=np.float32):
    import jax.numpy as jnp
    from repro.kernels.ragged_prefill import cu_seqlens, ragged_metadata
    rng = np.random.default_rng(seed)
    cu = cu_seqlens(lens)
    seg, pos = ragged_metadata(cu, total)
    q = jnp.asarray(rng.normal(size=(H, total, D)), dtype)
    k = jnp.asarray(rng.normal(size=(HK, total, D)), dtype)
    v = jnp.asarray(rng.normal(size=(HK, total, D)), dtype)
    return q, k, v, seg, pos, cu


class TestOracle:
    def test_ragged_lengths_match_the_masked_oracle(self):
        """Interpret-mode kernel vs the dense masked oracle on a ragged
        packing with an empty sequence and a padded tail."""
        from repro.core.families.ragged_prefill import RaggedPrefillConfig
        from repro.kernels.ragged_prefill import (ragged_prefill_attend,
                                                  ragged_prefill_ref)
        q, k, v, seg, pos, cu = _packed_case([60, 0, 100], 192)
        cfg = RaggedPrefillConfig(block_q=32, block_kv=32)
        got = ragged_prefill_attend(q, k, v, seg, pos, seg, pos,
                                    cfg=cfg, interpret=True)
        want = ragged_prefill_ref(q, k, v, seg, pos, seg, pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        # padding queries read nothing: exact zero rows
        assert float(np.abs(np.asarray(got)[:, 160:]).max()) == 0.0

    def test_full_buffer_single_sequence_is_plain_causal(self):
        """One sequence spanning the whole buffer degenerates to plain
        causal attention — cross-check against the flash oracle."""
        from repro.core.families.ragged_prefill import RaggedPrefillConfig
        from repro.kernels.flash_attention.ref import mha_ref
        from repro.kernels.ragged_prefill import ragged_prefill_attend
        q, k, v, seg, pos, _cu = _packed_case([128], 128)
        got = ragged_prefill_attend(
            q, k, v, seg, pos, seg, pos,
            cfg=RaggedPrefillConfig(block_q=32, block_kv=32),
            interpret=True)
        want = mha_ref(q[None], k[None], v[None], causal=True)[0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_poisoned_foreign_kv_never_reaches_other_sequences(self):
        """The leakage canary: fill one sequence's KV tokens AND every
        padding slot with sentinel garbage — all *other* sequences'
        outputs must be bit-identical to the clean run, and padding
        rows stay exactly zero.  (The runtime mirror of the family's
        gate-conformity invariant; extends the PR-8 poisoned-page
        oracle test to the prefill path.)"""
        from repro.core.families.ragged_prefill import RaggedPrefillConfig
        from repro.kernels.ragged_prefill import ragged_prefill_attend
        q, k, v, seg, pos, cu = _packed_case([48, 64, 30], 192, seed=3)
        cfg = RaggedPrefillConfig(block_q=32, block_kv=32)
        kw = dict(cfg=cfg, interpret=True)
        clean = np.asarray(ragged_prefill_attend(
            q, k, v, seg, pos, seg, pos, **kw))
        k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
        lo, hi = int(cu[1]), int(cu[2])      # sequence 1's packed span
        k2[:, lo:hi] = 1e6
        v2[:, lo:hi] = 1e6
        k2[:, int(cu[-1]):] = 1e6            # every padding slot
        v2[:, int(cu[-1]):] = 1e6
        import jax.numpy as jnp
        poisoned = np.asarray(ragged_prefill_attend(
            q, jnp.asarray(k2), jnp.asarray(v2), seg, pos, seg, pos,
            **kw))
        np.testing.assert_array_equal(clean[:, :lo], poisoned[:, :lo])
        np.testing.assert_array_equal(clean[:, hi:int(cu[-1])],
                                      poisoned[:, hi:int(cu[-1])])
        assert float(np.abs(poisoned[:, int(cu[-1]):]).max()) == 0.0

    def test_poisoned_padding_leaves_everything_bit_identical(self):
        """Sentinel garbage confined to padding (past cu[S]) must leave
        the *entire* output bit-identical — kernel and oracle agree."""
        import jax.numpy as jnp
        from repro.core.families.ragged_prefill import RaggedPrefillConfig
        from repro.kernels.ragged_prefill import (ragged_prefill_attend,
                                                  ragged_prefill_ref)
        q, k, v, seg, pos, cu = _packed_case([50, 70], 160, seed=5)
        cfg = RaggedPrefillConfig(block_q=32, block_kv=32)
        k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
        k2[:, int(cu[-1]):] = 1e6
        v2[:, int(cu[-1]):] = 1e6
        for fn, kw in ((ragged_prefill_attend,
                        dict(cfg=cfg, interpret=True)),
                       (ragged_prefill_ref, {})):
            clean = np.asarray(fn(q, k, v, seg, pos, seg, pos, **kw))
            poisoned = np.asarray(fn(q, jnp.asarray(k2), jnp.asarray(v2),
                                     seg, pos, seg, pos, **kw))
            np.testing.assert_array_equal(clean, poisoned)

    @pytest.mark.slow
    def test_interpret_mode_matches_dense_oracle(self):
        assert FAM.reference_check(CFG, PROB)

    def test_validated_entry_rejects_non_tiling_blocks(self):
        import jax.numpy as jnp
        from repro.core.families.ragged_prefill import RaggedPrefillConfig
        from repro.kernels.ragged_prefill import (InvariantViolation,
                                                  ragged_prefill_attend)
        q = jnp.zeros((2, 64, 32), jnp.float32)
        k = jnp.zeros((1, 64, 32), jnp.float32)
        seg = jnp.zeros((64,), jnp.int32)
        with pytest.raises(InvariantViolation):
            ragged_prefill_attend(
                q, k, k, seg, seg, seg, seg,
                cfg=RaggedPrefillConfig(block_q=48, block_kv=32),
                interpret=True)

    def test_verified_config_gate(self):
        from repro.kernels.ragged_prefill import verified_config
        cfg = verified_config(256, 256, 4, q_heads=8, kv_heads=2,
                              head_dim=64)
        assert cfg is not None
        assert 256 % cfg.block_q == 0 and 256 % cfg.block_kv == 0
        # a geometry no block can tile is unverifiable -> dense fallback
        assert verified_config(100, 100, 4, q_heads=8, kv_heads=2,
                               head_dim=64) is None
