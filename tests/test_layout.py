"""Layout algebra: property tests against brute-force oracles."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.layout import (Layout, brute_force_equal, logical_divide,
                               make_contiguous, view)


def small_layouts():
    shapes = st.lists(st.integers(1, 6), min_size=1, max_size=3)

    @st.composite
    def layout(draw):
        shp = tuple(draw(shapes))
        std = tuple(draw(st.integers(0, 12)) for _ in shp)
        return Layout(shp if len(shp) > 1 else shp[0],
                      std if len(std) > 1 else std[0])
    return layout()


@given(small_layouts())
@settings(max_examples=200, deadline=None)
def test_coalesce_preserves_function(l):
    assert brute_force_equal(l, l.coalesce())


@given(small_layouts())
@settings(max_examples=200, deadline=None)
def test_flat_preserves_function(l):
    assert brute_force_equal(l, l.flat())


@given(small_layouts())
@settings(max_examples=200, deadline=None)
def test_injectivity_matches_brute_force(l):
    claimed = l.is_injective()
    offsets = list(l.offsets())
    actual = len(set(offsets)) == len(offsets)
    # is_injective is allowed to be conservative (False on injective
    # layouts), never unsound (True on non-injective ones)
    if claimed:
        assert actual


def test_contiguous_row_major():
    l = make_contiguous((2, 3, 4))
    assert l((0, 0, 1)) == 1
    assert l((0, 1, 0)) == 4
    assert l((1, 0, 0)) == 12
    assert l.cosize == 24


def test_view_reshape_matches_numpy_colex():
    """The algebra's flat ordering is colexicographic (CuTe convention,
    paper ref [11]) — view() therefore matches Fortran-order reshape."""
    import numpy as np
    a = np.arange(24).reshape(4, 6, order="F")
    l = make_contiguous((4, 6), row_major=False)
    v = view(l, (2, 12), row_major=False)
    b = a.reshape(2, 12, order="F")
    flat = a.reshape(-1, order="F")
    for i in range(2):
        for j in range(12):
            assert flat[v((i, j))] == b[i, j]


def test_view_size_mismatch_rejected():
    with pytest.raises(ValueError):
        view(make_contiguous((4, 6)), (5, 5))


def test_logical_divide_tiles():
    l = make_contiguous((8, 8))
    t = logical_divide(l, (4, 4))
    # inner coordinate (1,1) within tile + outer tile (1,0)
    assert t(((1, 1), (0, 0))) == l((1, 1))
    assert t(((0, 0), (1, 0))) == l((4, 0))
    assert t(((2, 3), (1, 1))) == l((6, 7))


def test_right_inverse_roundtrip():
    l = Layout((4, 8), (8, 1))  # row-major 4x8
    r = l.right_inverse()
    for off in range(l.cosize):
        assert l(r(off)) == off


def test_nested_layout_wraps():
    # ((2,2),(…)) nested mode: flat index wraps around sub-extents
    l = Layout(((2, 2),), ((1, 4),))
    got = [l(i) for i in range(4)]
    assert got == [0, 1, 4, 5]
