"""Property tests for the ragged-prefill packing helpers
(:mod:`repro.kernels.ragged_prefill.packing`): cu_seqlens is monotone
and bounded, metadata round-trips lengths exactly (empty sequences and
full-buffer packings included), pack/unpack is an identity, and the
validators reject every malformed table.
"""
import numpy as np
import pytest

from repro.kernels.ragged_prefill import (PackingError, cu_seqlens,
                                          lengths_from_cu, pack_ragged,
                                          positions_from_cu,
                                          ragged_metadata,
                                          segment_ids_from_cu,
                                          unpack_ragged, validate_packing)

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

# ragged length lists: empty sequences allowed, small enough to stay fast
LENGTHS = st.lists(st.integers(0, 64), min_size=1, max_size=8)


@st.composite
def lengths_and_total(draw):
    """A length list plus a buffer size with room for padding."""
    lens = draw(LENGTHS)
    pad = draw(st.integers(0, 32))
    return lens, sum(lens) + pad


class TestCuSeqlens:
    @given(LENGTHS)
    @settings(max_examples=60, deadline=None)
    def test_monotone_bounded_and_round_trips(self, lens):
        cu = cu_seqlens(lens)
        assert cu.dtype == np.int32
        assert cu.shape == (len(lens) + 1,)
        assert cu[0] == 0
        assert (np.diff(cu) >= 0).all()
        assert cu[-1] == sum(lens)
        assert lengths_from_cu(cu).tolist() == lens
        validate_packing(cu, total=sum(lens))

    @given(lengths_and_total())
    @settings(max_examples=60, deadline=None)
    def test_metadata_round_trips_lengths(self, case):
        lens, total = case
        cu = cu_seqlens(lens)
        seg, pos = ragged_metadata(cu, total)
        assert seg.shape == pos.shape == (total,)
        # every sequence's token count survives the seg projection —
        # empty sequences simply never appear
        counts = [int((seg == s).sum()) for s in range(len(lens))]
        assert counts == lens
        # padding (and only padding) carries the fill id
        assert int((seg == -1).sum()) == total - sum(lens)
        assert (seg[sum(lens):] == -1).all()
        # positions restart at 0 inside each sequence and stay in range
        for s, n in enumerate(lens):
            p = pos[seg == s]
            assert (p == np.arange(n)).all()

    @given(lengths_and_total())
    @settings(max_examples=60, deadline=None)
    def test_segment_ids_and_positions_agree_with_metadata(self, case):
        lens, total = case
        cu = cu_seqlens(lens)
        seg, pos = ragged_metadata(cu, total)
        assert (seg == segment_ids_from_cu(cu, total)).all()
        assert (pos == positions_from_cu(cu, total)).all()

    def test_boundaries(self):
        # single empty sequence: all-padding metadata
        seg, pos = ragged_metadata(cu_seqlens([0]), 4)
        assert (seg == -1).all() and (pos == 0).all()
        # full buffer, no padding
        seg, _ = ragged_metadata(cu_seqlens([8]), 8)
        assert (seg == 0).all()
        # zero-size buffer is legal when every sequence is empty
        seg, pos = ragged_metadata(cu_seqlens([0, 0]), 0)
        assert seg.shape == (0,)


class TestPackRoundTrip:
    @given(st.lists(st.integers(0, 32), min_size=1, max_size=6),
           st.integers(0, 16), st.integers(1, 4))
    @settings(max_examples=60, deadline=None)
    def test_pack_then_unpack_is_identity(self, lens, pad, width):
        rng = np.random.default_rng(0)
        rows = [rng.normal(size=(n, width)).astype(np.float32)
                for n in lens]
        packed, cu = pack_ragged(rows, total=sum(lens) + pad)
        assert packed.shape == (sum(lens) + pad, width)
        assert lengths_from_cu(cu).tolist() == lens
        # padding rows are exact zeros
        assert float(np.abs(packed[sum(lens):]).max() if pad else 0) == 0
        out = unpack_ragged(packed, cu)
        assert len(out) == len(rows)
        for a, b in zip(out, rows):
            np.testing.assert_array_equal(a, b)

    def test_pack_overflow_rejected(self):
        with pytest.raises(PackingError):
            pack_ragged([np.zeros((4, 2), np.float32)], total=3)


class TestValidation:
    @pytest.mark.parametrize("cu,total", [
        ([1, 2], None),          # cu[0] != 0
        ([0, 3, 2], None),       # not monotone
        ([0, 5], 4),             # escapes the buffer
        ([], None),              # empty table
    ])
    def test_malformed_tables_rejected(self, cu, total):
        with pytest.raises(PackingError):
            validate_packing(np.asarray(cu, np.int32), total=total)
