"""Pallas kernels vs jnp oracles (interpret=True) — shape/dtype sweeps."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.invariants import (FlashAttentionConfig, GemmConfig,
                                   MoEConfig)
from repro.kernels.gemm import InvariantViolation, matmul, matmul_ref
from repro.kernels.flash_attention import mha, mha_ref
from repro.kernels.moe import (compute_dispatch, grouped_ffn,
                               grouped_ffn_ref, moe_ffn, moe_ffn_ref)

RNG = np.random.default_rng(0)


def _rel(o, w):
    o = np.asarray(o, np.float32)
    w = np.asarray(w, np.float32)
    return float(np.max(np.abs(o - w) / (np.abs(w) + 1.0)))


class TestGemmKernel:
    @pytest.mark.parametrize("m,n,k,dtype", [
        (256, 256, 256, jnp.float32),
        (256, 128, 512, jnp.bfloat16),
        (200, 130, 300, jnp.float32),     # masked tails
        (128, 384, 256, jnp.bfloat16),
    ])
    def test_matches_ref(self, m, n, k, dtype):
        a = jnp.asarray(RNG.normal(size=(m, k)), dtype)
        b = jnp.asarray(RNG.normal(size=(k, n)), dtype)
        err = _rel(matmul(a, b, interpret=True), matmul_ref(a, b))
        assert err < (2e-2 if dtype == jnp.bfloat16 else 1e-4)

    @pytest.mark.parametrize("cfg", [
        GemmConfig(stagger_k=True),
        GemmConfig(split_k=2),
        GemmConfig(split_k=4),
        GemmConfig(bm=64, bn=128, bk=128),
    ])
    def test_config_variants(self, cfg):
        a = jnp.asarray(RNG.normal(size=(256, 1024)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(1024, 256)), jnp.float32)
        err = _rel(matmul(a, b, cfg=cfg, interpret=True), matmul_ref(a, b))
        # f32 reassociation across K blocks / split partials: ~1e-5 level
        assert err < 1e-4, cfg.name()

    def test_invalid_config_rejected_before_lowering(self):
        # a config whose split doesn't divide K must be rejected by the
        # ARGUS gate (invariant machinery), not crash in pallas_call
        a = jnp.zeros((256, 384), jnp.float32)
        b = jnp.zeros((384, 256), jnp.float32)
        with pytest.raises((InvariantViolation, ValueError)):
            matmul(a, b, cfg=GemmConfig(split_k=7), interpret=True)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal", [
        (1, 2, 2, 256, 256, 64, True),
        (2, 8, 2, 256, 256, 64, True),       # GQA
        (1, 4, 1, 300, 300, 64, True),       # ragged tails (MQA)
        (1, 4, 4, 128, 384, 64, False),      # cross lengths, non-causal
    ])
    def test_matches_ref(self, b, hq, hkv, sq, skv, d, causal):
        q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)), jnp.float32)
        cfg = FlashAttentionConfig(block_q=128, block_kv=128,
                                   causal_block_skip=causal)
        o = mha(q, k, v, cfg=cfg, causal=causal, interpret=True)
        w = mha_ref(q, k, v, causal=causal)
        assert _rel(o, w) < 2e-3

    def test_bf16_numerics(self):
        q = jnp.asarray(RNG.normal(size=(1, 8, 256, 128)), jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(1, 2, 256, 128)), jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(1, 2, 256, 128)), jnp.bfloat16)
        o = mha(q, k, v, interpret=True,
                cfg=FlashAttentionConfig(128, 128))
        w = mha_ref(q, k, v)
        assert float(np.max(np.abs(np.asarray(o, np.float32)
                                   - np.asarray(w, np.float32)))) < 3e-2

    def test_gradient_path(self):
        q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 1, 128, 64)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 1, 128, 64)), jnp.float32)
        cfg = FlashAttentionConfig(64, 64)

        g1 = jax.grad(lambda q: mha(q, k, v, cfg=cfg,
                                    interpret=True).sum())(q)
        g2 = jax.grad(lambda q: mha_ref(q, k, v).sum())(q)
        assert _rel(g1, g2) < 5e-3


class TestMoEKernel:
    def test_grouped_ffn_matches_ref(self):
        E, C, DM, DF = 4, 64, 128, 256
        x = jnp.asarray(RNG.normal(size=(E, C, DM)), jnp.float32)
        wg = jnp.asarray(RNG.normal(size=(E, DM, DF)) * .05, jnp.float32)
        wu = jnp.asarray(RNG.normal(size=(E, DM, DF)) * .05, jnp.float32)
        wd = jnp.asarray(RNG.normal(size=(E, DF, DM)) * .05, jnp.float32)
        g = jnp.asarray(RNG.uniform(.2, 1, size=(E, C, 1)), jnp.float32)
        cfg = MoEConfig(block_t=32, block_f=128)
        o = grouped_ffn(x, wg, wu, wd, g, cfg=cfg, interpret=True)
        assert _rel(o, grouped_ffn_ref(x, wg, wu, wd, g)) < 1e-4

    def test_full_layer_matches_dense_oracle(self):
        T, E, K, DM, DF = 128, 8, 2, 64, 128
        x = jnp.asarray(RNG.normal(size=(T, DM)), jnp.float32)
        wg = jnp.asarray(RNG.normal(size=(E, DM, DF)) * .05, jnp.float32)
        wu = jnp.asarray(RNG.normal(size=(E, DM, DF)) * .05, jnp.float32)
        wd = jnp.asarray(RNG.normal(size=(E, DF, DM)) * .05, jnp.float32)
        logits = jnp.asarray(RNG.normal(size=(T, E)), jnp.float32)
        gates, idx = jax.lax.top_k(jax.nn.softmax(logits), K)
        o = moe_ffn(x, gates, idx.astype(jnp.int32), wg, wu, wd,
                    cfg=MoEConfig(block_t=32, block_f=64),
                    capacity_factor=8.0, interpret=True)
        w = moe_ffn_ref(x, gates, idx.astype(jnp.int32), wg, wu, wd)
        assert _rel(o, w) < 1e-4


class TestFlashDecodeKernel:
    @pytest.mark.parametrize("kv_len", [1, 128, 129, 700, 1024])
    def test_matches_ref_partial_cache(self, kv_len):
        from repro.core.invariants import FlashDecodeConfig
        from repro.kernels.flash_attention import mha_decode
        B, Hq, Hkv, S, D = 2, 8, 2, 1024, 64
        q = jnp.asarray(RNG.normal(size=(B, Hq, 1, D)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
        o = mha_decode(q, k, v, jnp.int32(kv_len),
                       cfg=FlashDecodeConfig(kv_splits=8), interpret=True)
        w = mha_ref(q, k, v, causal=False, kv_len=kv_len)
        assert float(np.max(np.abs(np.asarray(o) - np.asarray(w)))) < 1e-4

    @pytest.mark.parametrize("bug", ["wrong_kv_head", "split_overlap",
                                     "partial_mislabel"])
    def test_invariants_catch_bugs(self, bug):
        from repro.core.invariants import (FlashDecodeConfig,
                                           FlashDecodeProblem,
                                           verify_flash_decode)
        prob = FlashDecodeProblem(batch=4, q_heads=8, kv_heads=2,
                                  seq_kv=32768, head_dim=128)
        assert verify_flash_decode(FlashDecodeConfig(8), prob).hard_ok
        assert not verify_flash_decode(FlashDecodeConfig(8), prob,
                                       inject_bug=bug).hard_ok


class TestSSDKernel:
    def test_matches_ref(self):
        from repro.core.invariants import SSDConfig
        from repro.kernels.ssd import ssd, ssd_ref
        BH, S, P, N, q = 2, 256, 32, 16, 64
        x = jnp.asarray(RNG.normal(size=(BH, S, P)), jnp.float32)
        da = jnp.asarray(-np.abs(RNG.normal(size=(BH, S))) * .1,
                         jnp.float32)
        Bm = jnp.asarray(RNG.normal(size=(BH, S, N)) * .3, jnp.float32)
        Cm = jnp.asarray(RNG.normal(size=(BH, S, N)) * .3, jnp.float32)
        y = ssd(x, da, Bm, Cm, cfg=SSDConfig(chunk=q), interpret=True)
        w, _ = ssd_ref(x, da, Bm, Cm, q)
        assert _rel(y, w) < 1e-4

    def test_matches_model_ssd(self):
        """The Pallas SSD path equals the model's chunked-einsum path."""
        from repro.models.ssm import ssd_chunked, ssd_via_kernel
        B, S, H, P, N, q = 1, 128, 2, 16, 8, 32
        xh = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
        da = jnp.asarray(-np.abs(RNG.normal(size=(B, S, H))) * .1,
                         jnp.float32)
        Bh = jnp.asarray(RNG.normal(size=(B, S, H, N)) * .3, jnp.float32)
        Ch = jnp.asarray(RNG.normal(size=(B, S, H, N)) * .3, jnp.float32)
        y1, _ = ssd_chunked(xh, da, Bh, Ch, q)
        y2 = ssd_via_kernel(xh, da, Bh, Ch, q, interpret=True)
        assert _rel(y1, y2) < 1e-4

    @pytest.mark.parametrize("bug", ["b_chunk_offset", "state_depends_c",
                                     "xb_mismatch"])
    def test_invariants_catch_bugs(self, bug):
        from repro.core.invariants import SSDConfig, SSDProblem, verify_ssd
        prob = SSDProblem(batch_heads=8, seq=1024, head_dim=64, d_state=64)
        assert verify_ssd(SSDConfig(chunk=128), prob).hard_ok
        assert not verify_ssd(SSDConfig(chunk=128), prob,
                              inject_bug=bug).hard_ok


class TestDispatchProperties:
    def test_capacity_respected_and_dests_valid(self):
        pytest.importorskip(
            "hypothesis",
            reason="hypothesis not installed — "
                   "pip install -r requirements-dev.txt")
        from hypothesis import given, settings, strategies as st

        @given(st.integers(0, 10_000), st.integers(2, 16),
               st.integers(1, 4))
        @settings(max_examples=30, deadline=None)
        def prop(seed, E, K):
            rng = np.random.default_rng(seed)
            T, C = 64, 16
            idx = jnp.asarray(rng.integers(0, E, size=(T, K)), jnp.int32)
            dest, keep = compute_dispatch(idx, E, C)
            dest, keep = np.asarray(dest), np.asarray(keep)
            flat_d = dest.reshape(-1)[keep.reshape(-1)]
            flat_e = np.asarray(idx).reshape(-1)[keep.reshape(-1)]
            # kept slots land inside their expert's capacity range
            assert np.all(flat_d // C == flat_e)
            # no two kept pairs share a slot
            assert len(set(flat_d.tolist())) == len(flat_d)
            # per-expert count never exceeds capacity
            for e in range(E):
                assert np.sum(flat_e == e) <= C

        prop()
