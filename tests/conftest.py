import os
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches must see the real
# device count (the 512-device override lives only in launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="run slow (interpret-mode sweep / train) tests")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
