"""Alpha-renaming canonicalizer: renamed / assertion-reordered programs
produce identical constraint-cache keys and identical verdicts, and a
canonical warm cache (in-memory or persisted) yields 0 solver discharges
across different configs — and differently-*named* programs — with
congruent constraints."""
import pytest

from repro.core import dsl
from repro.core.analysis import Analyzer
from repro.core.families import get_family
from repro.core.tags import Expr, Var, make_tag
from repro.core.verify_engine import (CachingDischarger, ConstraintCache,
                                      VerificationEngine, canonical_key)


# ---------------------------------------------------------------------------
# canonical_key directly
# ---------------------------------------------------------------------------

class TestCanonicalKey:
    def test_alpha_renamed_keys_identical(self):
        k1 = ("zero", (Var("g_i", 4) * 128 + Var("g_j", 8),))
        k2 = ("zero", (Var("p", 4) * 128 + Var("q", 8),))
        assert canonical_key(k1) == canonical_key(k2)

    def test_extents_are_load_bearing(self):
        k1 = ("zero", (Var("g_i", 4) * 128 + Var("g_j", 8),))
        k3 = ("zero", (Var("g_i", 4) * 128 + Var("g_j", 16),))
        assert canonical_key(k1) != canonical_key(k3), \
            "same shape, different domain must not collide"

    def test_rename_that_flips_sort_order_still_shares(self):
        # "a" < "l0" but "z" > "b": the stored (name-sorted) term order
        # differs between these congruent keys; the canonicalizer must
        # assign indices in a name-free order to share them
        k1 = ("zero", (Var("a", 4) * 128 + Var("l0", 128),))
        k2 = ("zero", (Var("z", 4) * 128 + Var("b", 128),))
        assert canonical_key(k1) == canonical_key(k2)

    def test_tied_variables_share_via_global_signature(self):
        # within i+j the two variables tie on (coefficient, shape) —
        # only the key's second element tells them apart.  The tie must
        # be broken by each variable's *global* occurrence signature,
        # not its name: by-name, these congruent keys canonicalize
        # apart (the historical cache miss this test pins)
        i, j = Var("a", 4), Var("b", 4)
        assert canonical_key(("pair", i + j, i)) \
            == canonical_key(("pair", i + j, j))

    def test_mod_structure_and_tables_survive(self):
        from repro.core.tags import app
        e1 = (Var("g_k", 8) + Var("g_i", 4)) % 8 + app("tbl", Var("g_i", 4),
                                                       20)
        e2 = (Var("r", 8) + Var("p", 4)) % 8 + app("tbl", Var("p", 4), 20)
        assert canonical_key(("inj", e1)) == canonical_key(("inj", e2))
        # a different table is a different function — must NOT share
        e3 = (Var("r", 8) + Var("p", 4)) % 8 + app("other", Var("p", 4), 20)
        assert canonical_key(("inj", e1)) != canonical_key(("inj", e3))

    def test_property_random_renamings_share(self):
        pytest.importorskip(
            "hypothesis",
            reason="hypothesis not installed — "
                   "pip install -r requirements-dev.txt")
        from hypothesis import given, settings, strategies as st

        @given(st.integers(0, 2 ** 31), st.permutations(list(range(5))))
        @settings(max_examples=60, deadline=None)
        def prop(seed, perm):
            import random
            rng = random.Random(seed)
            extents = [rng.choice((2, 4, 8, 128)) for _ in range(5)]
            names_a = [f"v{i}" for i in range(5)]
            names_b = [f"w{perm[i]}" for i in range(5)]   # renamed/permuted

            def build(names):
                vs = [Var(n, e) for n, e in zip(names, extents)]
                e = Expr.of(rng.randrange(-4, 5))
                state = rng.getstate()
                for v in vs:
                    c = rng.randrange(-256, 257)
                    e = e + v * c
                    if rng.random() < 0.3:
                        e = e % rng.choice((4, 8, 256))
                return e, state

            rng.seed(seed)
            e_a, _ = build(names_a)
            rng.seed(seed)
            e_b, _ = build(names_b)
            assert canonical_key(("zero", (e_a,))) \
                == canonical_key(("zero", (e_b,)))

        prop()


# ---------------------------------------------------------------------------
# Congruent tile programs (renamed axes/tensors, reordered assertions)
# ---------------------------------------------------------------------------

def _mini_stagger_gemm(axes=("i", "j", "k"), tensors=("A", "B", "C"),
                       reorder=False) -> dsl.TileProgram:
    """A small stagger-K GEMM whose constraint set spans conformity,
    injectivity, stability, disjointness and coverage."""
    p = dsl.TileProgram(f"mini_{axes[0]}{tensors[0]}")
    i = p.add_grid(axes[0], 4)
    j = p.add_grid(axes[1], 4)
    k = p.add_grid(axes[2], 8, "arbitrary")
    A, B, C = tensors
    p.tensor(A, (512, 1024))
    p.tensor(B, (1024, 512))
    p.tensor(C, (512, 512), kind="output")
    k_idx = (Expr.of(k) + i + j) % 8
    a = p.load(A, (i * 128, k_idx * 128), (128, 128))
    b = p.load(B, (k_idx * 128, j * 128), (128, 128))
    acc = p.alloc((128, 128), "f32")
    p.assert_contraction(a, b, components=((1,), (0,)))
    p.matmul(a, b, accumulate=True, acc=acc,
             retag=lambda li, lj: make_tag(i * 128 + li, j * 128 + lj))
    p.store(C, acc, (i * 128, j * 128))
    asserts = [lambda: p.assert_injective(k_idx, (axes[2],)),
               lambda: p.assert_stable(acc, axes[2]),
               lambda: p.assert_disjoint_writes(C),
               lambda: p.assert_coverage(C)]
    if reorder:
        asserts.reverse()
    for add in asserts:
        add()
    return p


def _diag_shift(axes=("i", "j")) -> dsl.TileProgram:
    """Diagonal-staggered load: block (i, j) reads row-block (i+j)%4.
    The staggered index *ties* the two axes — same coefficient, same
    extent — while the injectivity obligation pins only the first, so
    within that one constraint key the tie is broken by context
    elsewhere in the key, never by the variables' shapes alone."""
    p = dsl.TileProgram(f"diag_{axes[0]}{axes[1]}")
    i = p.add_grid(axes[0], 4)
    j = p.add_grid(axes[1], 4)
    p.tensor("A", (512, 512))
    p.tensor("C", (512, 512), kind="output")
    diag = (Expr.of(i) + j) % 4
    a = p.load("A", (diag * 128, j * 128), (128, 128))
    p.store("C", a, (i * 128, j * 128))
    p.assert_injective(diag, (axes[0],))
    p.assert_disjoint_writes("C")
    p.assert_coverage("C")
    return p


def _statuses(report):
    return sorted(r.status.value for _, r in report.results)


class TestCongruentPrograms:
    def test_renamed_reordered_program_same_verdict_zero_discharges(self):
        cache = ConstraintCache()
        r1 = Analyzer(_mini_stagger_gemm(),
                      discharger=CachingDischarger(cache)).run()
        misses_cold = cache.misses
        assert r1.ok and misses_cold > 0
        r2 = Analyzer(
            _mini_stagger_gemm(axes=("p", "q", "r"),
                               tensors=("X", "Y", "Z"), reorder=True),
            discharger=CachingDischarger(cache)).run()
        assert r2.ok
        assert _statuses(r1) == _statuses(r2)
        assert cache.misses == misses_cold, \
            "congruent renamed program must re-discharge nothing"
        assert cache.canonical_hits > 0, \
            "the sharing must come from canonical keys, not raw ones"

    def test_tied_axes_with_swapped_names_rediscarge_nothing(self):
        # the same diagonal-stagger program with the two (equal-extent)
        # axis names swapped: a pure renaming that flips the name-sorted
        # storage order of the tied pair inside (i+j)%4.  The former
        # by-name tie-break canonicalized the injectivity obligation
        # apart and re-discharged it; the global occurrence signature
        # must share it
        cache = ConstraintCache()
        r1 = Analyzer(_diag_shift(("i", "j")),
                      discharger=CachingDischarger(cache)).run()
        misses_cold = cache.misses
        assert r1.ok and misses_cold > 0
        r2 = Analyzer(_diag_shift(("j", "i")),
                      discharger=CachingDischarger(cache)).run()
        assert r2.ok
        assert _statuses(r1) == _statuses(r2)
        assert cache.misses == misses_cold, \
            "swapped-name tied axes must re-discharge nothing"
        assert cache.canonical_hits > 0

    def test_canonical_warm_cache_persists_across_naming(self, tmp_path):
        path = tmp_path / "constraint_cache.json"
        cache = ConstraintCache()
        Analyzer(_mini_stagger_gemm(),
                 discharger=CachingDischarger(cache)).run()
        assert cache.save(path) > 0

        warm = ConstraintCache()
        assert warm.load(path) > 0
        r = Analyzer(
            _mini_stagger_gemm(axes=("p", "q", "r"),
                               tensors=("X", "Y", "Z"), reorder=True),
            discharger=CachingDischarger(warm)).run()
        assert r.ok
        assert warm.misses == 0, \
            "persisted canonical verdicts must warm the renamed program"
        assert warm.persisted_hits > 0


class TestCrossConfigSharing:
    """Different *configs* with congruent constraints: flash attention
    with and without the in-kernel causal mask traces one elementwise op
    less, shifting every later tile/local number — raw keys would
    diverge wherever locals survive, canonical keys must not."""

    FA = get_family("flash_attention")

    def _prob(self):
        return self.FA.problem_cls(2, 8, 2, 2048, 2048, 128)

    def test_zero_discharges_across_congruent_configs(self):
        eng = VerificationEngine()
        r1 = eng.verify("flash_attention", self.FA.config_cls(), self._prob())
        assert r1.hard_ok
        before = eng.stats()["solver_discharges"]
        r2 = eng.verify("flash_attention",
                        self.FA.config_cls(applies_mask=False), self._prob())
        assert r2.hard_ok
        assert eng.stats()["solver_discharges"] == before, \
            "congruent constraints across configs must all hit the cache"

    def test_warm_start_across_congruent_configs(self, tmp_path):
        path = tmp_path / "constraint_cache.json"
        cold = VerificationEngine()
        cold.verify("flash_attention", self.FA.config_cls(), self._prob())
        assert cold.constraints.save(path) > 0

        warm_cache = ConstraintCache()
        warm_cache.load(path)
        warm = VerificationEngine(constraints=warm_cache)
        warm.verify("flash_attention",
                    self.FA.config_cls(applies_mask=False), self._prob())
        s = warm.stats()
        assert s["solver_discharges"] == 0, s
        assert s["persisted_hits"] > 0


class TestSkeletonReuse:
    """The engine's incremental program build: one full build per
    structural class, re-binds for every congruent config, and no
    re-traces at all once the program memo is warm."""

    GEMM = get_family("gemm")

    def test_one_full_build_then_rebinds(self):
        eng = VerificationEngine()
        prob = self.GEMM.problem_cls(2048, 2048, 2048, "bf16")
        cfgs = [self.GEMM.config_cls(bm=bm, bn=bn)
                for bm, bn in ((128, 128), (256, 128), (128, 256),
                               (256, 256), (512, 128))]
        for cfg in cfgs:
            eng.verify("gemm", cfg, prob)
        s = eng.stats()
        assert s["full_builds"] == 1, s
        assert s["skeleton_rebinds"] == len(cfgs) - 1, s

    def test_structural_change_is_a_full_build(self):
        eng = VerificationEngine()
        prob = self.GEMM.problem_cls(2048, 2048, 2048, "bf16")
        eng.verify("gemm", self.GEMM.config_cls(), prob)
        eng.verify("gemm", self.GEMM.config_cls(split_k=2), prob)
        s = eng.stats()
        # split_k adds a grid axis: a genuinely new skeleton
        assert s["full_builds"] == 2 and s["skeleton_rebinds"] == 0, s

    def test_repeat_run_never_retraces(self):
        eng = VerificationEngine()
        prob = self.GEMM.problem_cls(2048, 2048, 2048, "bf16")
        cfgs = [self.GEMM.config_cls(bm=bm) for bm in (128, 256, 512)]
        for cfg in cfgs:
            eng.verify("gemm", cfg, prob)
        # fresh-process analogue: results gone, programs + constraints warm
        eng.drop_results()
        eng.reset_stats()
        for cfg in cfgs:
            eng.verify("gemm", cfg, prob)
        s = eng.stats()
        assert s["full_builds"] == 0 and s["skeleton_rebinds"] == 0, s
        assert s["program_hits"] == len(cfgs), s
        assert s["solver_discharges"] == 0, s
