"""End-to-end system tests: training loop convergence + resume, serving
engine, HLO analysis, and the dry-run machinery on a reduced cell."""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

REPO = Path(__file__).resolve().parent.parent


class TestHloAnalysis:
    def test_collective_parsing(self):
        from repro.launch.hlo_analysis import collective_bytes
        hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), dims={0}
  %ar = f32[256,256]{1,0} all-reduce(f32[256,256]{1,0} %y), to_apply=%add
  %rs = f32[8,32]{1,0} reduce-scatter(f32[64,32]{1,0} %z), dims={0}
  %cp = bf16[4,4]{1,0} collective-permute(bf16[4,4]{1,0} %w)
  %notacoll = f32[2,2]{1,0} add(f32[2,2] %a, f32[2,2] %b)
"""
        st = collective_bytes(hlo)
        assert st.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                    "reduce-scatter": 1,
                                    "collective-permute": 1}
        assert st.bytes_by_kind["all-gather"] == 16 * 1024 * 2
        assert st.bytes_by_kind["all-reduce"] == 2 * 256 * 256 * 4

    def test_roofline_terms(self):
        from repro.launch.hlo_analysis import Roofline
        r = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=50e9,
                     n_chips=1, model_flops=100e12)
        assert abs(r.compute_s - 1.0) < 1e-9
        assert abs(r.memory_s - 1.0) < 1e-9
        assert abs(r.collective_s - 1.0) < 1e-9
        assert 0.5 < r.useful_flops_frac < 0.52


class TestTrainLoop:
    @pytest.mark.slow
    def test_loss_drops_and_resumes(self, tmp_path):
        from repro.launch import train as train_mod
        args = ["--arch", "qwen3-1.7b", "--reduced", "--steps", "30",
                "--batch", "4", "--seq", "64",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "15",
                "--log-every", "10"]
        losses = train_mod.main(args)
        assert losses[-1] < losses[0]
        # resume continues from step 30's checkpoint
        losses2 = train_mod.main(args + ["--resume", "--steps", "35"])
        assert len(losses2) == 5

    def test_train_step_runs_with_grad_accum(self):
        from repro import configs
        from repro.models import build
        from repro.optim import adamw_init
        from repro.train import make_train_step
        cfg = configs.get_reduced("stablelm-3b")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = make_train_step(model, lr_fn=lambda s: 1e-3, grad_accum=2)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 32), 2, cfg.vocab)}
        params, opt, metrics = jax.jit(step)(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(opt.step) == 1


class TestServingEngine:
    def test_ragged_slots_match_solo_runs(self):
        """Slots with different prompt lengths decode the same tokens as
        running each request alone — per-slot cache positions are exact."""
        from repro import configs
        from repro.models import build
        from repro.serve import Request, ServingEngine
        cfg = configs.get_reduced("qwen3-1.7b")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = [[5, 6, 7], [9, 10, 11, 12, 13, 14, 15, 16]]

        solo = []
        for p in prompts:
            eng = ServingEngine(model, params, n_slots=1, max_len=48,
                                eos_id=-1)
            eng.submit(Request(0, p, max_new_tokens=6))
            solo.append(eng.run()[0].output)

        eng = ServingEngine(model, params, n_slots=2, max_len=48,
                            eos_id=-1)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid, p, max_new_tokens=6))
        done = sorted(eng.run(), key=lambda r: r.rid)
        for got, want in zip(done, solo):
            assert got.output == want, (got.output, want)

    def test_continuous_batching_completes(self):
        from repro import configs
        from repro.models import build
        from repro.serve import Request, ServingEngine
        cfg = configs.get_reduced("gemma-7b")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, n_slots=2, max_len=48,
                            eos_id=-1)
        for rid in range(5):
            eng.submit(Request(rid, [3, 4, 5, 6], max_new_tokens=4))
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.output) == 4 for r in done)

    @pytest.mark.parametrize("kind", ["dense", "paged"])
    def test_metrics_contract(self, kind):
        """Both engines honour the ServingMetrics contract: one record
        per step, monotonic counters, gauges that agree with the engine's
        actual queue/occupancy after every tick, and a snapshot that
        round-trips through from_snapshot."""
        from repro import configs
        from repro.models import build
        from repro.serve import (PagedServingEngine, Request,
                                 ServingEngine, ServingMetrics)
        cfg = configs.get_reduced("qwen3-1.7b")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if kind == "dense":
            eng = ServingEngine(model, params, n_slots=2, max_len=32,
                                eos_id=-1)
        else:
            eng = PagedServingEngine(model, params, pool_pages=9,
                                     page_size=8, max_batch=2,
                                     max_len=32, prefill_chunk=8,
                                     eos_id=-1)
        rng = np.random.default_rng(4)
        for rid in range(6):
            plen = int(rng.integers(3, 14))
            eng.submit(Request(rid,
                               rng.integers(2, cfg.vocab,
                                            size=plen).tolist(),
                               max_new_tokens=int(rng.integers(3, 7))))

        prev = eng.metrics.snapshot()
        ticks = 0
        while eng.queue or (any(s.req is not None for s in eng.slots)
                            if kind == "dense" else eng.active):
            eng.step()
            ticks += 1
            snap = eng.metrics.snapshot()
            # counters are monotonic and ticks advance exactly once/step
            for k, v in snap["counters"].items():
                assert v >= prev["counters"][k], (k, v, prev)
            assert snap["counters"]["ticks"] == ticks
            # gauges agree with the engine state after the step
            assert snap["gauges"]["queue_depth"] == len(eng.queue)
            if kind == "dense":
                occ = sum(1 for s in eng.slots if s.req is not None)
                assert snap["gauges"]["active"] == occ
                assert snap["gauges"]["occupancy"] == occ
            else:
                assert snap["gauges"]["active"] == len(eng.active)
                assert (snap["gauges"]["occupancy"]
                        == eng.alloc.used_pages)
            assert snap["gauges"]["occupancy"] <= snap["capacity"]
            prev = snap
            assert ticks < 500, "engine failed to drain"

        snap = eng.metrics.snapshot()
        assert snap["kind"] == kind
        assert snap["counters"]["finished"] == 6 == len(eng.finished)
        total_out = sum(len(r.output) for r in eng.finished)
        # every admission (re-admissions included) yields one token from
        # prefill logits; all other tokens are decode-tick tokens
        assert (snap["counters"]["decode_tokens"]
                == total_out - snap["counters"]["admitted"])
        want_prefill = sum(len(r.prompt) for r in eng.finished)
        if snap["counters"]["preempted"]:
            # recompute-style resume re-prefills prompt + generated-so-far
            assert snap["counters"]["prefill_tokens"] > want_prefill
        else:
            assert snap["counters"]["prefill_tokens"] == want_prefill
        # snapshot round-trip is exact
        rt = ServingMetrics.from_snapshot(snap)
        assert rt.snapshot() == snap
        with pytest.raises(ValueError, match="schema"):
            ServingMetrics.from_snapshot({**snap, "schema": 99})


class TestDryRunMachinery:
    @pytest.mark.slow
    def test_reduced_cell_compiles_on_forced_mesh(self):
        """Run the dry-run driver in a subprocess with 32 fake devices and
        a reduced config: proves the lower+compile+analyze path without the
        cost of a production mesh."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import sys; sys.path.insert(0, "src")
import jax
from pathlib import Path
import repro.launch.dryrun as dr
import repro.launch.mesh as mesh_mod
mesh_mod.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 2, 8) if multi_pod else (4, 8),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
dr.make_production_mesh = mesh_mod.make_production_mesh
import repro.configs as C
dr.configs.get_config = C.get_reduced
rec = dr.run_cell("qwen3-1.7b", "train_4k", False, Path("/tmp/dr_test"))
assert rec["roofline"]["flops"] > 0
rec = dr.run_cell("qwen3-1.7b", "decode_32k", True, Path("/tmp/dr_test"))
assert rec["n_chips"] == 32
print("DRYRUN_MACHINERY_OK")
"""
        out = subprocess.run([sys.executable, "-c", code], cwd=REPO,
                             capture_output=True, text=True, timeout=420)
        assert "DRYRUN_MACHINERY_OK" in out.stdout, out.stderr[-2000:]
