"""Fleet tuner: job model, successive-halving scheduler, journal
resumability (including a real mid-run SIGKILL), worker-count
determinism of the dispatch table, and the serving dispatch hooks."""
import copy
import json
import os
import re
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.core.families import all_families, get_family
from repro.core.harness import (KernelState, OptimizeCheckpoint, Planner,
                                Selector, Validator, optimize_kernel)
from repro.core.tuning import (AsyncSuccessiveHalving, DispatchTable,
                               GapBandit, Journal, JournalMismatch,
                               SolPolicy, SuccessiveHalving,
                               enumerate_jobs, make_job,
                               reconcile_schedule, run_fleet,
                               shape_bucket, stable_seed)
from repro.core.tuning import dispatch as dispatch_mod
from repro.core.tuning.dispatch import SCHEMA_EXAMPLE
from repro.core.verify_engine import VerificationEngine, merge_stats

ROOT = Path(__file__).resolve().parent.parent
GEMM = get_family("gemm")

FAST_FAMILIES = ["gemm", "quant_gemm"]
FAST = dict(base_budget=2, max_budget=4)


def _fleet(tmp, workers=1, families=FAST_FAMILIES, **kw):
    jobs = enumerate_jobs(families, seed=0)
    merged = {**FAST, **kw}
    return run_fleet(jobs, workers=workers, out_dir=tmp, **merged)


# ---------------------------------------------------------------------------
# Job model
# ---------------------------------------------------------------------------

class TestJobs:
    def test_every_example_family_becomes_a_job(self):
        jobs = enumerate_jobs(seed=0)
        expect = {f.name for f in all_families() if f.example is not None}
        assert {j.family for j in jobs} == expect

    def test_seeds_are_stable_and_decorrelated(self):
        a = enumerate_jobs(seed=0)
        b = enumerate_jobs(seed=0)
        assert [j.seed for j in a] == [j.seed for j in b]
        assert len({j.seed for j in a}) == len(a), \
            "per-job seeds must differ across (family, problem)"
        c = enumerate_jobs(seed=1)
        assert all(x.seed != y.seed for x, y in zip(a, c)), \
            "the base seed must reshuffle every job's stream"

    def test_stable_seed_is_content_derived(self):
        assert stable_seed("gemm", "p", 0) == stable_seed("gemm", "p", 0)
        assert stable_seed("gemm", "p", 0) != stable_seed("moe", "p", 0)

    def test_priority_orders_by_modeled_cost(self):
        jobs = enumerate_jobs(seed=0)
        assert [j.priority for j in jobs] == \
            sorted((j.priority for j in jobs), reverse=True)

    def test_sweep_emits_one_job_per_grid_bucket(self):
        plain = enumerate_jobs(seed=0)
        swept = enumerate_jobs(seed=0, sweep=True)
        assert len(swept) > len(plain), \
            "sweep=True must add shape-grid jobs"
        assert {j.job_id for j in plain} <= {j.job_id for j in swept}, \
            "every example() job must survive the sweep"
        for fam in all_families():
            if fam.sweep_problems is None:
                continue
            buckets = [shape_bucket(j.problem) for j in swept
                       if j.family == fam.name]
            assert len(set(buckets)) == len(buckets), \
                f"{fam.name}: sweep problems collide in a dispatch bucket"
            _, ex = fam.example()
            assert shape_bucket(ex) in buckets

    def test_sweep_is_deterministic_and_opt_in(self):
        assert [j.job_id for j in enumerate_jobs(seed=0, sweep=True)] \
            == [j.job_id for j in enumerate_jobs(seed=0, sweep=True)]
        assert [j.job_id for j in enumerate_jobs(seed=0)] \
            == [j.job_id for j in enumerate_jobs(seed=0, sweep=False)]


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def _fake_jobs(n):
    return [make_job("gemm", GEMM.problem_cls(512 * (i + 1), 512, 512))
            for i in range(n)]


class TestSuccessiveHalving:
    def test_budgets_double_and_survivors_halve(self):
        jobs = _fake_jobs(4)
        sched = SuccessiveHalving(jobs, base_budget=2, max_budget=8)
        rung0 = sched.first_rung()
        assert len(rung0) == 4 and all(it.budget == 2 for it in rung0)
        records = {it.job.job_id: {"speedup": 1.0 + i}
                   for i, it in enumerate(rung0)}
        rung1 = sched.next_rung(records)
        assert len(rung1) == 2 and all(it.budget == 4 for it in rung1)
        best_two = sorted(records, key=lambda j: -records[j]["speedup"])[:2]
        assert {it.job.job_id for it in rung1} == set(best_two)
        assert all(it.checkpoint is records[it.job.job_id]
                   for it in rung1)
        records1 = {it.job.job_id: {"speedup": 2.0} for it in rung1}
        rung2 = sched.next_rung(records1)
        assert len(rung2) == 1 and rung2[0].budget == 8
        assert sched.next_rung(
            {rung2[0].job.job_id: {"speedup": 2.0}}) == []

    def test_incomplete_rung_is_an_error(self):
        sched = SuccessiveHalving(_fake_jobs(2), base_budget=1,
                                  max_budget=2)
        sched.first_rung()
        with pytest.raises(ValueError, match="incomplete"):
            sched.next_rung({})

    def test_single_job_rides_every_rung(self):
        sched = SuccessiveHalving(_fake_jobs(1), base_budget=1,
                                  max_budget=4)
        items = sched.first_rung()
        budgets = []
        while items:
            budgets.append(items[0].budget)
            items = sched.next_rung(
                {items[0].job.job_id: {"speedup": 1.0}})
        assert budgets == [1, 2, 4]


# ---------------------------------------------------------------------------
# Async (rung-free) scheduler + reconciliation
# ---------------------------------------------------------------------------

def _rec(item, speedup):
    return {"kind": "result", "item": item.item_id,
            "job": item.job.job_id, "rung": item.rung,
            "speedup": speedup}


class TestAsyncSuccessiveHalving:
    def test_promotes_from_completed_peers_without_a_barrier(self):
        sched = AsyncSuccessiveHalving(_fake_jobs(4), base_budget=2,
                                       max_budget=8)
        items = {it.job.job_id: it for it in sched.initial_items()}
        ids = sorted(items)
        # one completion: 1 // 2 == 0 — nothing promotable yet
        assert sched.on_result(_rec(items[ids[0]], 2.0)) == []
        # second, worse, completion: the first enters the top half and
        # promotes with its own record as checkpoint — no waiting for
        # the two jobs still in flight
        promoted = sched.on_result(_rec(items[ids[1]], 1.1))
        assert [it.job.job_id for it in promoted] == [ids[0]]
        assert promoted[0].rung == 1 and promoted[0].budget == 4
        assert promoted[0].checkpoint["speedup"] == 2.0

    def test_straggler_cannot_delay_unrelated_promotions(self):
        """The tentpole property: every other job finishes rung 0 and
        keeps promoting up the ladder while one straggler never
        reports."""
        jobs = _fake_jobs(5)
        sched = AsyncSuccessiveHalving(jobs, base_budget=2, max_budget=8)
        items = sched.initial_items()
        straggler = items[0].job.job_id
        promoted = []
        frontier = [it for it in items if it.job.job_id != straggler]
        while frontier:
            it = frontier.pop(0)
            new = sched.on_result(_rec(it, 2.0 + it.budget))
            promoted += new
            frontier += new
        assert promoted, "peers must promote despite the straggler"
        assert straggler not in {it.job.job_id for it in promoted}
        assert max(it.rung for it in promoted) == 2, \
            "the ladder must be climbable to the top without the " \
            "straggler"

    def test_a_late_good_result_still_promotes(self):
        sched = AsyncSuccessiveHalving(_fake_jobs(4), base_budget=2,
                                       max_budget=4)
        items = {it.job.job_id: it for it in sched.initial_items()}
        ids = sorted(items)
        for jid in ids[:3]:
            sched.on_result(_rec(items[jid], 1.5))
        late = sched.on_result(_rec(items[ids[3]], 9.0))
        assert any(it.job.job_id == ids[3] for it in late), \
            "rank re-evaluation must promote a late fast finisher"

    def test_duplicate_and_foreign_results_are_ignored(self):
        sched = AsyncSuccessiveHalving(_fake_jobs(2), base_budget=2,
                                       max_budget=4)
        a, b = sched.initial_items()
        first = sched.on_result(_rec(a, 3.0)) + sched.on_result(_rec(b, 1.0))
        assert [it.item_id for it in first] == [f"{a.job.job_id}@r1"]
        assert sched.on_result(_rec(a, 3.0)) == [], \
            "a re-delivered result must not re-issue the promotion"
        assert sched.on_result({"job": "ghost:job", "rung": 0,
                                "speedup": 9.9}) == []


class TestReconcileSchedule:
    def test_selects_exactly_the_sync_schedule(self):
        jobs = _fake_jobs(4)
        sync = SuccessiveHalving(jobs, base_budget=2, max_budget=8)
        records, sync_items = {}, []
        items = sync.first_rung()
        while items:
            sync_items += [it.item_id for it in items]
            for it in items:
                records[it.item_id] = _rec(it, 1.0 + it.job.priority)
            items = sync.next_rung(
                {it.job.job_id: records[it.item_id] for it in items})
        # speculative async extra that sync would never have run
        loser = sorted(jobs, key=lambda j: j.job_id)[-1]
        records[f"{loser.job_id}@r2"] = {"kind": "result",
                                         "job": loser.job_id, "rung": 2,
                                         "speedup": 99.0}
        selected, missing = reconcile_schedule(jobs, records,
                                               base_budget=2,
                                               max_budget=8)
        assert missing == []
        assert set(selected) == set(sync_items), \
            "reconciliation must select the sync schedule and drop " \
            "speculative extras"

    def test_reports_the_first_incomplete_rung(self):
        jobs = _fake_jobs(3)
        sched = SuccessiveHalving(jobs, base_budget=2, max_budget=4)
        rung0 = sched.first_rung()
        records = {it.item_id: _rec(it, 1.0) for it in rung0[:-1]}
        selected, missing = reconcile_schedule(jobs, records,
                                               base_budget=2,
                                               max_budget=4)
        assert selected == {}
        assert [it.item_id for it in missing] == [rung0[-1].item_id]
        # completing it unblocks rung 1 with embedded checkpoints
        records[rung0[-1].item_id] = _rec(rung0[-1], 1.0)
        selected, missing = reconcile_schedule(jobs, records,
                                               base_budget=2,
                                               max_budget=4)
        assert set(selected) == {it.item_id for it in rung0}
        assert all(it.rung == 1 and it.checkpoint is not None
                   for it in missing)


# ---------------------------------------------------------------------------
# Speed-of-light guidance: early stop, bandit, reconciliation with grants
# ---------------------------------------------------------------------------

def _srec(item, speedup, sol_frac):
    rec = _rec(item, speedup)
    rec.update({"budget": item.budget, "sol_frac": sol_frac})
    return rec


class TestSolPolicy:
    def test_stop_rule_threshold(self):
        pol = SolPolicy(slack=0.1)
        assert pol.stops({"sol_frac": 1.0})
        assert pol.stops({"sol_frac": 0.91})     # 0.91 * 1.1 >= 1
        assert not pol.stops({"sol_frac": 0.90})
        assert not pol.stops({"sol_frac": None})
        assert not pol.stops({})                 # pre-SoL journal record

    def test_bandit_is_deterministic_and_rotates(self):
        def drive(seed):
            b = GapBandit(SolPolicy(seed=seed))
            b.observe("a", 0.30, 2)
            b.observe("b", 0.28, 2)
            return [b.grant(("a", "b")) for _ in range(4)]

        assert drive("fp") == drive("fp"), \
            "same fingerprint must replay the same grant sequence"
        grants = drive("fp")
        assert set(grants) == {"a", "b"}, \
            "pull-count decay must rotate the budget across arms"
        # unobserved arms tie on score: the fingerprint-salted hash must
        # still order them deterministically
        c1 = GapBandit(SolPolicy(seed="x")).grant(("p", "q"))
        c2 = GapBandit(SolPolicy(seed="x")).grant(("p", "q"))
        assert c1 == c2

    def test_extras_never_feed_back(self):
        b = GapBandit(SolPolicy(seed="fp"))
        b.observe("a", 0.5, 0)       # zero-budget observation: ignored
        assert b._obs == {}


class TestSolScheduler:
    def test_no_stops_means_the_plain_schedule(self):
        """With every record far from its bound the SoL scheduler must
        issue exactly the plain scheduler's items."""
        jobs = _fake_jobs(4)
        plain = SuccessiveHalving(jobs, base_budget=2, max_budget=8)
        sol = SuccessiveHalving(jobs, base_budget=2, max_budget=8,
                                sol=SolPolicy(seed="fp"))
        pi, si = plain.first_rung(), sol.first_rung()
        while pi or si:
            assert [it.item_id for it in pi] == [it.item_id for it in si]
            recs_p = {it.job.job_id: _srec(it, 1.0 + it.job.priority, 0.2)
                      for it in pi}
            pi = plain.next_rung(recs_p)
            si = sol.next_rung(recs_p)
        assert sol.stopped == {} and sol.freed_iterations == 0

    def test_stopped_job_occupies_its_slot_and_frees_the_budget(self):
        jobs = _fake_jobs(4)
        sched = SuccessiveHalving(jobs, base_budget=2, max_budget=8,
                                  sol=SolPolicy(slack=0.1, seed="fp"))
        rung0 = sched.first_rung()
        a, b, c, d = sorted(rung0, key=lambda it: it.job.job_id)
        # a is at the floor AND ranks first: it wins a rung-1 slot but
        # must not run — only b promotes, a's slot budget is freed
        recs = {a.job.job_id: _srec(a, 4.0, 1.0),
                b.job.job_id: _srec(b, 3.0, 0.5),
                c.job.job_id: _srec(c, 2.0, 0.4),
                d.job.job_id: _srec(d, 1.5, 0.3)}
        rung1 = sched.next_rung({j: recs[j] for j in recs})
        assert [it.job.job_id for it in rung1] == [b.job.job_id]
        assert a.job.job_id in sched.stopped
        assert sched.freed_iterations == 4       # a's rung-1 budget
        # rung 2: a's frozen 4.0 still outranks b's 3.5 — keep=1 keeps
        # the frozen job, nothing promotes, the whole rung budget frees
        # and the bandit re-grants chunks to the cut-but-unstopped jobs
        items = sched.next_rung(
            {b.job.job_id: _srec(rung1[0], 3.5, 0.6)})
        assert sched.freed_iterations == 4 + 8
        assert all(it.extra for it in items), \
            "no live promotion — only bandit extras may run"
        assert sched.granted_iterations == sum(it.budget for it in items)
        assert sched.granted_iterations <= 12 * 0.25
        for it in items:
            assert it.job.job_id not in sched.stopped
            assert it.item_id.endswith(f"+e{it.extra}")
            assert it.checkpoint is not None \
                and it.rung == it.checkpoint["rung"]

    def test_frozen_rank_never_changes_who_else_promotes(self):
        """Promotions among non-stopped jobs must match the plain
        schedule exactly — the frozen record occupies its slot with a
        lower-bound score, so no other job's fate changes."""
        jobs = _fake_jobs(6)
        plain = SuccessiveHalving(jobs, base_budget=2, max_budget=8)
        sol = SuccessiveHalving(jobs, base_budget=2, max_budget=8,
                                sol=SolPolicy(seed="fp"))

        def recs(items, stopped_frac):
            return {it.job.job_id: _srec(
                it, 1.0 + it.job.priority,
                stopped_frac if it is items[0] else 0.2)
                for it in items}

        pi, si = plain.first_rung(), sol.first_rung()
        # stop the top-ranked job at rung 0 in the sol run only
        p_next = plain.next_rung(recs(pi, 0.2))
        s_next = sol.next_rung(recs(si, 1.0))
        stopped = {j for j in sol.stopped}
        assert stopped
        assert [it.job.job_id for it in p_next
                if it.job.job_id not in stopped] \
            == [it.job.job_id for it in s_next if not it.extra]

    def test_reconcile_replays_stops_and_grants(self):
        """Driving the sol scheduler to completion and reconciling with
        the same policy must select exactly the driven items — extras
        included — while the plain reconciliation drops them."""
        jobs = _fake_jobs(4)
        pol = SolPolicy(seed="fp")
        sched = SuccessiveHalving(jobs, base_budget=2, max_budget=8,
                                  sol=pol)
        items, records, driven = sched.first_rung(), {}, []
        fracs = {}
        while items:
            driven += [it.item_id for it in items]
            for it in items:
                f = fracs.get(it.job.job_id, 0.0) \
                    + (0.9 if it.job is jobs[0] else 0.25)
                fracs[it.job.job_id] = f
                records[it.item_id] = _srec(it, 1.0 + f, min(f, 1.0))
            items = sched.next_rung(
                {it.job.job_id: records[it.item_id] for it in items
                 if not it.extra})
        assert sched.stopped, "the fast-closing job must hit the floor"
        assert any("+e" in i for i in driven), \
            "the drive must exercise bandit extras"
        selected, missing = reconcile_schedule(
            jobs, records, base_budget=2, max_budget=8, sol=pol)
        assert missing == []
        assert set(selected) == set(driven)
        plain_sel, _ = reconcile_schedule(jobs, records, base_budget=2,
                                          max_budget=8)
        assert not any("+e" in i for i in plain_sel), \
            "without the policy, extras are speculation and stay out"

    def test_async_suppresses_promotion_of_stopped_jobs(self):
        jobs = _fake_jobs(2)
        pol = SolPolicy(seed="fp")
        sched = AsyncSuccessiveHalving(jobs, base_budget=2, max_budget=4,
                                       sol=pol)
        a, b = sched.initial_items()
        out = sched.on_result(_srec(a, 3.0, 1.0)) \
            + sched.on_result(_srec(b, 1.0, 0.2))
        assert out == [], \
            "the top job is at the floor: async must not promote it"


class TestSolFleet:
    def test_records_are_stamped_and_summary_reported(self, tmp_path):
        rep = _fleet(tmp_path, sol=True)
        assert all(r.get("sol_frac") is not None
                   for r in rep.records.values()), \
            "every gemm/quant_gemm record must carry its sol fraction"
        assert set(rep.sol) == {"stopped", "freed_iterations",
                                "granted_iterations"}
        for jid, frac in rep.sol["stopped"].items():
            assert frac * 1.1 >= 1.0, (jid, frac)
        table = dispatch_mod.load(tmp_path / "dispatch_table.json")
        for buckets in table.entries.values():
            for e in buckets.values():
                assert "sol_frac" in e["provenance"]

    def test_sol_knobs_are_part_of_the_fingerprint(self, tmp_path):
        """Stops change which items exist, so a non-sol journal must not
        satisfy a --sol run (and vice versa) — but a matching --sol
        re-invocation resumes everything."""
        r1 = _fleet(tmp_path, sol=True)
        with pytest.raises(JournalMismatch):
            _fleet(tmp_path)
        with pytest.raises(JournalMismatch):
            _fleet(tmp_path, sol=True, sol_slack=0.2)
        r2 = _fleet(tmp_path, sol=True)
        assert r2.ran == 0 and r2.skipped == r1.ran

    def test_sol_async_and_resume_reproduce_the_sync_table(
            self, tmp_path):
        _fleet(tmp_path / "sync", sol=True)
        ref = (tmp_path / "sync" / "dispatch_table.json").read_bytes()
        _fleet(tmp_path / "async", sol=True, async_mode=True)
        assert (tmp_path / "async" /
                "dispatch_table.json").read_bytes() == ref
        # kill/resume: drop the journal's last record and re-invoke
        jpath = tmp_path / "sync" / "fleet_journal.jsonl"
        lines = jpath.read_text().splitlines()
        jpath.write_text("\n".join(lines[:-1]) + "\n")
        r = _fleet(tmp_path / "sync", sol=True)
        assert r.ran == 1
        assert (tmp_path / "sync" /
                "dispatch_table.json").read_bytes() == ref


# ---------------------------------------------------------------------------
# Journal
# ---------------------------------------------------------------------------

class TestJournal:
    def test_round_trip_and_torn_tail(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl")
        assert j.start("fp") == {}
        j.append({"kind": "result", "item": "a@r0", "x": 1})
        j.append({"kind": "result", "item": "b@r0", "x": 2})
        # simulate a kill mid-append: torn, unparseable final line
        with open(j.path, "a") as fh:
            fh.write('{"kind": "result", "item": "c@r0", "x"')
        got = j.start("fp")
        assert set(got) == {"a@r0", "b@r0"}
        assert got["a@r0"]["x"] == 1

    def test_fingerprint_mismatch_refuses_unless_fresh(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl")
        j.start("fp1")
        j.append({"kind": "result", "item": "a@r0"})
        with pytest.raises(JournalMismatch):
            j.start("fp2")
        assert j.start("fp2", fresh=True) == {}

    def test_append_after_torn_tail_seals_the_fragment(self, tmp_path):
        """A resumed run appending after a kill-mid-append must not
        concatenate onto the torn fragment (which would lose the new
        record too) — the fragment gets sealed with a newline first."""
        j = Journal(tmp_path / "j.jsonl")
        j.start("fp")
        j.append({"kind": "result", "item": "a@r0", "x": 1})
        with open(j.path, "a") as fh:
            fh.write('{"kind": "result", "item": "b@r0", "x"')
        j.append({"kind": "result", "item": "b@r0", "x": 2})
        got = j.start("fp")
        assert set(got) == {"a@r0", "b@r0"}
        assert got["b@r0"]["x"] == 2

    def test_later_record_wins_for_same_item(self, tmp_path):
        j = Journal(tmp_path / "j.jsonl")
        j.start("fp")
        j.append({"kind": "result", "item": "a@r0", "x": 1})
        j.append({"kind": "result", "item": "a@r0", "x": 2})
        assert j.records()["a@r0"]["x"] == 2


# ---------------------------------------------------------------------------
# Dispatch table
# ---------------------------------------------------------------------------

class TestDispatchTable:
    def test_schema_example_validates(self):
        DispatchTable(copy.deepcopy(SCHEMA_EXAMPLE))

    def test_missing_field_and_bad_config_are_rejected(self):
        broken = copy.deepcopy(SCHEMA_EXAMPLE)
        entry = next(iter(next(iter(
            broken["entries"].values())).values()))
        del entry["provenance"]
        with pytest.raises(ValueError, match="provenance"):
            DispatchTable(broken)
        broken = copy.deepcopy(SCHEMA_EXAMPLE)
        next(iter(next(iter(
            broken["entries"].values())).values()))["config"]["bogus"] = 1
        with pytest.raises(ValueError, match="reconstruct"):
            DispatchTable(broken)
        broken = copy.deepcopy(SCHEMA_EXAMPLE)
        broken["entries"]["no_such_family"] = {}
        with pytest.raises(ValueError, match="unregistered"):
            DispatchTable(broken)

    def test_shape_bucket_rounds_ints_up_to_pow2(self):
        a = shape_bucket(GEMM.problem_cls(5000, 8000, 4100, "bf16"))
        b = shape_bucket(GEMM.problem_cls(8192, 8192, 8192, "bf16"))
        assert a == b == "m=8192,n=8192,k=8192,dtype=bf16"

    def test_lookup_and_config_for(self):
        t = DispatchTable(copy.deepcopy(SCHEMA_EXAMPLE))
        prob = GEMM.problem_cls(5000, 8000, 4100, "bf16")
        cfg = t.config_for("gemm", prob)
        assert isinstance(cfg, GEMM.config_cls) and cfg.stagger_k
        assert t.config_for("gemm",
                            GEMM.problem_cls(64, 64, 64, "bf16")) is None

    def test_install_and_configured(self):
        prob = GEMM.problem_cls(8192, 8192, 8192, "bf16")
        try:
            dispatch_mod.install(copy.deepcopy(SCHEMA_EXAMPLE))
            cfg = dispatch_mod.configured("gemm", prob)
            assert cfg == GEMM.config_cls(bm=256, bn=256, bk=512,
                                          stagger_k=True)
        finally:
            dispatch_mod.install(None)
        assert dispatch_mod.configured("gemm", prob) is None

    def test_configured_skips_configs_invalid_for_the_exact_problem(self):
        """Buckets are coarse: a winner tuned at the bucket
        representative may be invalid for a smaller in-bucket shape.
        ``configured`` must return None there (caller falls back to its
        default) instead of letting the gate crash the call."""
        table = copy.deepcopy(SCHEMA_EXAMPLE)
        entry = table["entries"]["gemm"]["m=8192,n=8192,k=8192,dtype=bf16"]
        entry["config"]["split_k"] = 4          # 8192/512 = 16 K blocks
        try:
            dispatch_mod.install(table)
            rep = GEMM.problem_cls(8192, 8192, 8192, "bf16")
            assert dispatch_mod.configured("gemm", rep) is not None
            # k=5000 buckets up to 8192 but has 10 K blocks — split_k=4
            # does not divide it, so the tuned config must be skipped
            odd = GEMM.problem_cls(8192, 8192, 5000, "bf16")
            assert dispatch_mod.configured("gemm", odd) is None
        finally:
            dispatch_mod.install(None)


# ---------------------------------------------------------------------------
# Budgeted optimize_kernel checkpoints
# ---------------------------------------------------------------------------

class TestOptimizeCheckpoint:
    def test_resumed_slice_continues_the_budgeted_run(self):
        prob = GEMM.problem_cls(2048, 2048, 2048, "bf16")
        engine = VerificationEngine()

        def slice_(ckpt, seed):
            st = KernelState("gemm", GEMM.config_cls(), prob).refresh()
            return optimize_kernel(
                st, planner=Planner(),
                selector=Selector(temperature=0.1, seed=seed),
                validator=Validator(engine=engine),
                iterations=3, checkpoint=ckpt)

        r0 = slice_(None, 1)
        ck = r0.checkpoint()
        assert isinstance(ck, OptimizeCheckpoint)
        assert ck.iterations_done == len(r0.history)
        r1 = slice_(ck, 2)
        assert r1.baseline_time_s == r0.baseline_time_s, \
            "resume must keep the original baseline (cumulative speedup)"
        assert r1.best_time_s <= r0.best_time_s, \
            "a resumed slice can only improve on the incumbent"
        assert r1.iterations_done == ck.iterations_done + len(r1.history)


# ---------------------------------------------------------------------------
# Fleet orchestration
# ---------------------------------------------------------------------------

class TestFleet:
    def test_serial_run_produces_valid_artifacts(self, tmp_path):
        rep = _fleet(tmp_path)
        assert rep.ran > 0 and rep.skipped == 0
        table = dispatch_mod.load(tmp_path / "dispatch_table.json")
        assert set(table.entries) == set(FAST_FAMILIES)
        legacy = json.loads((tmp_path / "tuning_cache.json").read_text())
        assert set(legacy) == set(FAST_FAMILIES)
        assert all("config" in v and "est_ms" in v
                   for v in legacy.values())
        assert rep.stats.get("verify_calls", 0) > 0

    def test_rerun_resumes_everything_from_journal(self, tmp_path):
        r1 = _fleet(tmp_path)
        before = (tmp_path / "dispatch_table.json").read_bytes()
        r2 = _fleet(tmp_path)
        assert r2.ran == 0 and r2.skipped == r1.ran
        assert (tmp_path / "dispatch_table.json").read_bytes() == before

    def test_truncated_journal_reruns_only_missing_items(self, tmp_path):
        _fleet(tmp_path)
        ref = (tmp_path / "dispatch_table.json").read_bytes()
        jpath = tmp_path / "fleet_journal.jsonl"
        lines = jpath.read_text().splitlines()
        jpath.write_text("\n".join(lines[:-1]) + "\n")   # lose last item
        r = _fleet(tmp_path)
        assert r.ran == 1 and r.skipped == len(lines) - 2
        assert (tmp_path / "dispatch_table.json").read_bytes() == ref

    def test_changed_budgets_refuse_stale_journal(self, tmp_path):
        _fleet(tmp_path)
        with pytest.raises(JournalMismatch):
            _fleet(tmp_path, max_budget=8)
        r = _fleet(tmp_path, max_budget=8, fresh=True)   # --fresh
        assert r.ran > 0

    def test_run_kernels_flag_is_part_of_the_fingerprint(self, tmp_path):
        """A journal written without the interpret-mode oracle gate must
        not satisfy a --run-kernels run: the flag changes verdicts."""
        _fleet(tmp_path)
        with pytest.raises(JournalMismatch):
            _fleet(tmp_path, run_kernels=True)

    @pytest.mark.multiproc
    def test_dispatch_table_identical_across_worker_counts(
            self, tmp_path):
        """The acceptance determinism property, in miniature: parallel
        workers sharing caches must produce byte-for-byte the serial
        run's dispatch table."""
        r1 = _fleet(tmp_path / "serial", workers=1)
        r2 = _fleet(tmp_path / "fleet", workers=2)
        t1 = (tmp_path / "serial" / "dispatch_table.json").read_bytes()
        t2 = (tmp_path / "fleet" / "dispatch_table.json").read_bytes()
        assert t1 == t2
        assert r2.stats["solver_discharges"] \
            < 2 * max(r1.stats["solver_discharges"], 1), \
            "cache sharing should keep 2 workers below 2x solo discharges"


# ---------------------------------------------------------------------------
# Async fleet: reconciled determinism + shared lessons
# ---------------------------------------------------------------------------

class TestFleetAsync:
    def test_async_serial_reconciles_to_the_sync_table(self, tmp_path):
        r_sync = _fleet(tmp_path / "sync", workers=1)
        r_async = _fleet(tmp_path / "async", workers=1, async_mode=True)
        t1 = (tmp_path / "sync" / "dispatch_table.json").read_bytes()
        t2 = (tmp_path / "async" / "dispatch_table.json").read_bytes()
        assert t1 == t2, \
            "async + reconciliation must reproduce the sync table"
        assert r_async.rungs == r_sync.rungs

    @pytest.mark.multiproc
    def test_async_workers_reconcile_to_the_sync_table(self, tmp_path):
        """The acceptance property across *both* axes at once: 2 async
        workers vs 1 sync worker — scheduling order, promotion rule and
        worker count all differ, the reconciled table must not."""
        _fleet(tmp_path / "sync", workers=1)
        _fleet(tmp_path / "async", workers=2, async_mode=True)
        assert (tmp_path / "sync" / "dispatch_table.json").read_bytes() \
            == (tmp_path / "async" / "dispatch_table.json").read_bytes()

    def test_async_resumes_from_sync_journal_without_rerunning(
            self, tmp_path):
        """Mode is excluded from the journal fingerprint: an item's
        result does not depend on the promotion rule, so a sync journal
        fully satisfies an async re-invocation."""
        r1 = _fleet(tmp_path)
        r2 = _fleet(tmp_path, async_mode=True)
        assert r2.ran == 0 and r2.skipped >= r1.ran

    def test_lessons_flow_cross_family_and_fingerprint_guards(
            self, tmp_path):
        """A serial sweep run with the lesson store on: later items must
        import lessons published by earlier items of *other* families
        (the generic skills carry them), and the lessons flag must be
        part of the journal fingerprint — trajectories differ, so a
        lessons journal must not satisfy a non-lessons run."""
        jobs = enumerate_jobs(FAST_FAMILIES, seed=0, sweep=True)
        rep = run_fleet(jobs, workers=1, out_dir=tmp_path,
                        lessons=True, **FAST)
        assert rep.lessons["lessons_published"] > 0
        assert rep.lessons["lessons_imported"] > 0
        assert rep.lessons["lessons_reused"] > 0, \
            "a sweep over two GEMM-shaped families must reuse lessons " \
            "across them"
        store = json.loads((tmp_path / "lessons.json").read_text())
        assert store["version"] == 1 and store["lessons"]
        assert {e["family"] for e in store["lessons"].values()} \
            == set(FAST_FAMILIES)
        with pytest.raises(JournalMismatch):
            run_fleet(jobs, workers=1, out_dir=tmp_path, **FAST)


# ---------------------------------------------------------------------------
# Kill-and-resume regression (the orchestrator must survive SIGKILL)
# ---------------------------------------------------------------------------

_CLI = [sys.executable, "examples/argus_optimize.py",
        "--workers", "2", "--family", "gemm", "--family", "quant_gemm",
        "--family", "moe", "--base-budget", "2", "--max-budget", "4"]
_DONE = re.compile(r"fleet done: \d+ rungs, (\d+) items ran, "
                   r"(\d+) resumed from the journal")


@pytest.mark.multiproc
def test_kill_mid_run_resumes_without_rerunning_finished(tmp_path):
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    ref_dir, out_dir = tmp_path / "ref", tmp_path / "killed"

    # uninterrupted reference
    ref = subprocess.run(_CLI + ["--out-dir", str(ref_dir)], cwd=ROOT,
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert ref.returncode == 0, ref.stderr

    # start, wait for the first journaled result, SIGKILL the orchestrator
    proc = subprocess.Popen(_CLI + ["--out-dir", str(out_dir)], cwd=ROOT,
                            env=env, stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)
    journal = out_dir / "fleet_journal.jsonl"
    deadline = time.monotonic() + 120
    while time.monotonic() < deadline and proc.poll() is None:
        if journal.exists() and \
                '"kind": "result"' in journal.read_text():
            break
        time.sleep(0.05)
    if proc.poll() is None:
        proc.send_signal(signal.SIGKILL)
    proc.wait(timeout=30)
    finished_before = len(Journal(journal).records())
    assert journal.exists(), "journal never appeared before the kill"

    # resume: must complete, skipping exactly the journaled items
    res = subprocess.run(_CLI + ["--out-dir", str(out_dir)], cwd=ROOT,
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert res.returncode == 0, res.stderr
    m = _DONE.search(res.stdout)
    assert m, res.stdout
    assert int(m.group(2)) == finished_before, \
        "every journaled item must resume, none re-run"
    assert (out_dir / "dispatch_table.json").read_bytes() == \
        (ref_dir / "dispatch_table.json").read_bytes(), \
        "a killed+resumed run must converge to the uninterrupted table"

    # third invocation: everything journaled, --expect-resume gate holds
    res2 = subprocess.run(
        _CLI + ["--out-dir", str(out_dir), "--expect-resume"], cwd=ROOT,
        env=env, capture_output=True, text=True, timeout=300)
    assert res2.returncode == 0, res2.stdout + res2.stderr


# ---------------------------------------------------------------------------
# Cross-worker stats aggregation
# ---------------------------------------------------------------------------

def test_merge_stats_sums_counters_and_maxes_the_gauge():
    merged = merge_stats([
        {"verify_calls": 3, "solver_discharges": 5,
         "cached_constraints": 40},
        {"verify_calls": 2, "solver_discharges": 1,
         "cached_constraints": 7},
    ])
    assert merged["verify_calls"] == 5
    assert merged["solver_discharges"] == 6
    assert merged["cached_constraints"] == 40
