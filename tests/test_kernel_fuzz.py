"""Registry-wide differential fuzz harness.

Every registered kernel family gets the same treatment: draw a
downscaled problem shape from the family's ``sweep_problems()`` menu,
perturb it with a seeded rng, fill the inputs from the same rng, run
the Pallas kernel in interpret mode and diff it against the package's
jnp oracle.  Seeds derive from :func:`repro.core.tuning.jobs
.stable_seed` (the tuner's process-stable hash), so a red run prints a
``(family, case, seed)`` triple that reproduces byte-for-byte on any
host — paste it into ``_rng`` and replay.

The harness is deliberately registry-driven: a new family that
registers without adding an adapter here FAILS (not skips), so kernel
coverage cannot silently lag the registry.
"""
import numpy as np
import pytest

from repro.core.families import family_names, get_family
from repro.core.tuning.jobs import stable_seed

# bounded for CI: per family, |CASES| sweep-derived shapes x |TRIALS|
# input draws.  Raise locally for a deeper soak.
CASES = (0, 1)
TRIALS = (0, 1)


def _rng(family: str, case: int, trial: int):
    seed = stable_seed(f"fuzz:{family}:{case}:{trial}")
    return np.random.default_rng(seed), seed


def _pick(rng, options):
    return options[int(rng.integers(len(options)))]


# ---------------------------------------------------------------------------
# Per-family adapters: downscale a sweep problem, perturb it with the
# seeded rng, run interpret-mode kernel vs oracle.  Each returns
# (got, want, rtol, atol, shape-description).
# ---------------------------------------------------------------------------

def _fuzz_gemm(prob, rng):
    import jax.numpy as jnp
    from repro.core.families.gemm import GemmConfig
    from repro.kernels.gemm import matmul, matmul_ref
    b = _pick(rng, (16, 32))
    m, n, k = (int(rng.integers(1, 5)) * b for _ in range(3))
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = matmul(a, w, cfg=GemmConfig(bm=b, bn=b, bk=b), interpret=True)
    return got, matmul_ref(a, w), 1e-3, 1e-3, f"m={m} n={n} k={k} b={b}"


def _fuzz_flash_attention(prob, rng):
    import jax.numpy as jnp
    from repro.core.families.flash_attention import FlashAttentionConfig
    from repro.kernels.flash_attention import mha, mha_ref
    blk = _pick(rng, (16, 32))
    sq = int(rng.integers(2, 5)) * blk
    skv = int(rng.integers(2, 5)) * blk
    causal = bool(prob.causal) and sq == skv
    d = _pick(rng, (32, 64))
    hk = _pick(rng, (1, 2))
    hq = hk * _pick(rng, (1, 2, 4))
    q = jnp.asarray(rng.normal(size=(1, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, hk, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, hk, skv, d)), jnp.float32)
    cfg = FlashAttentionConfig(block_q=blk, block_kv=blk)
    got = mha(q, k, v, cfg=cfg, causal=causal, interpret=True)
    return (got, mha_ref(q, k, v, causal=causal), 2e-3, 2e-3,
            f"sq={sq} skv={skv} d={d} h={hq}:{hk} causal={causal}")


def _fuzz_flash_decode(prob, rng):
    import jax.numpy as jnp
    from repro.core.families.flash_decode import FlashDecodeConfig
    from repro.kernels.flash_attention import mha_decode, mha_ref
    splits = _pick(rng, (2, 4))
    S = int(rng.integers(2, 9)) * splits * 8
    d = _pick(rng, (32, 64))
    hk = _pick(rng, (1, 2))
    hq = hk * _pick(rng, (1, 4))
    q = jnp.asarray(rng.normal(size=(1, hq, 1, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, hk, S, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, hk, S, d)), jnp.float32)
    got = mha_decode(q, k, v, jnp.int32(S),
                     cfg=FlashDecodeConfig(kv_splits=splits),
                     interpret=True)
    return (got, mha_ref(q, k, v, causal=False), 2e-3, 2e-3,
            f"S={S} d={d} h={hq}:{hk} splits={splits}")


def _fuzz_moe(prob, rng):
    import jax.numpy as jnp
    from repro.core.families.moe import MoEConfig
    from repro.kernels.moe import grouped_ffn, grouped_ffn_ref
    E = _pick(rng, (2, 4))
    C = int(rng.integers(1, 4)) * 8
    DM = _pick(rng, (32, 64))
    DF = _pick(rng, (64, 128))
    x = jnp.asarray(rng.normal(size=(E, C, DM)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(E, DM, DF)) * .05, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(E, DM, DF)) * .05, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(E, DF, DM)) * .05, jnp.float32)
    cfg = MoEConfig(block_t=8, block_f=64)
    got = grouped_ffn(x, wg, wu, wd, cfg=cfg, interpret=True)
    return (got, grouped_ffn_ref(x, wg, wu, wd), 2e-3, 2e-3,
            f"E={E} C={C} DM={DM} DF={DF}")


def _fuzz_ssd(prob, rng):
    import jax.numpy as jnp
    from repro.core.families.ssd import SSDConfig
    from repro.kernels.ssd import ssd, ssd_ref
    chunk = _pick(rng, (16, 32))
    S = int(rng.integers(2, 5)) * chunk
    BH = _pick(rng, (1, 2))
    d = _pick(rng, (16, 32))
    ds = _pick(rng, (8, 16))
    x = jnp.asarray(rng.normal(size=(BH, S, d)), jnp.float32)
    da = jnp.asarray(-np.abs(rng.normal(size=(BH, S))) * .1, jnp.float32)
    B = jnp.asarray(rng.normal(size=(BH, S, ds)) * .3, jnp.float32)
    C = jnp.asarray(rng.normal(size=(BH, S, ds)) * .3, jnp.float32)
    got = ssd(x, da, B, C, cfg=SSDConfig(chunk=chunk), interpret=True)
    want = ssd_ref(x, da, B, C, chunk)[0]
    return got, want, 2e-3, 2e-3, f"BH={BH} S={S} d={d} N={ds} q={chunk}"


def _fuzz_quant_gemm(prob, rng):
    from repro.core.families.quant_gemm import QuantGemmConfig
    from repro.kernels.quant_gemm import (quant_matmul, quant_matmul_ref,
                                          quantize_per_group)
    group = _pick(rng, (32, 64))
    m = int(rng.integers(1, 5)) * 32
    n = int(rng.integers(1, 5)) * 32
    k = int(rng.integers(1, 4)) * group
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    aq, sa = quantize_per_group(a, group, axis=1)
    bq, sb = quantize_per_group(b, group, axis=0)
    cfg = QuantGemmConfig(bm=32, bn=32, bk=32)
    got = quant_matmul(aq, bq, sa, sb, group=group, cfg=cfg,
                       interpret=True)
    want = quant_matmul_ref(aq, bq, sa, sb, group=group)
    return got, want, 2e-2, 2e-2, f"m={m} n={n} k={k} g={group}"


def _fuzz_paged_attention(prob, rng):
    import jax.numpy as jnp
    from repro.core.families.paged_attention import PagedAttentionConfig
    from repro.kernels.paged_attention import (paged_decode,
                                               paged_decode_ref)
    B = _pick(rng, (2, 3))
    PS = _pick(rng, (8, 16))
    NP = _pick(rng, (2, 4))
    d = _pick(rng, (32, 64))
    hk = _pick(rng, (1, 2))
    hq = hk * _pick(rng, (1, 4))
    P = B * NP + 2
    q = jnp.asarray(rng.normal(size=(B, hq, 1, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, hk, PS, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, hk, PS, d)), jnp.float32)
    table = jnp.asarray(rng.permutation(P)[:B * NP].reshape(B, NP),
                        jnp.int32)
    lens = jnp.asarray(rng.integers(0, NP * PS + 1, size=(B,)), jnp.int32)
    cfg = PagedAttentionConfig(block_pages=_pick(rng, (1, 2)))
    got = paged_decode(q, kp, vp, table, lens, cfg=cfg, interpret=True)
    want = paged_decode_ref(q, kp, vp, table, lens)
    return (got, want, 2e-3, 2e-3,
            f"B={B} PS={PS} NP={NP} d={d} h={hq}:{hk} "
            f"lens={list(map(int, lens))}")


def _fuzz_ragged_prefill(prob, rng):
    import jax.numpy as jnp
    from repro.core.families.ragged_prefill import RaggedPrefillConfig
    from repro.kernels.ragged_prefill import (cu_seqlens, ragged_metadata,
                                              ragged_prefill_attend,
                                              ragged_prefill_ref)
    blk = _pick(rng, (16, 32))
    total = int(rng.integers(3, 7)) * blk
    n_seqs = _pick(rng, (1, 2, 3))
    # random ragged split (empty sequences allowed), padded tail
    cuts = np.sort(rng.integers(0, total + 1, size=n_seqs))
    lens = np.diff(np.concatenate([[0], cuts])).tolist()
    cu = cu_seqlens(lens)
    seg, pos = ragged_metadata(cu, total)
    d = _pick(rng, (32, 64))
    hk = _pick(rng, (1, 2))
    hq = hk * _pick(rng, (1, 2))
    q = jnp.asarray(rng.normal(size=(hq, total, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(hk, total, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(hk, total, d)), jnp.float32)
    cfg = RaggedPrefillConfig(block_q=blk, block_kv=blk)
    got = ragged_prefill_attend(q, k, v, seg, pos, seg, pos, cfg=cfg,
                                interpret=True)
    want = ragged_prefill_ref(q, k, v, seg, pos, seg, pos)
    return (got, want, 2e-3, 2e-3,
            f"lens={lens} total={total} d={d} h={hq}:{hk} blk={blk}")


ADAPTERS = {
    "gemm": _fuzz_gemm,
    "flash_attention": _fuzz_flash_attention,
    "flash_decode": _fuzz_flash_decode,
    "moe": _fuzz_moe,
    "ssd": _fuzz_ssd,
    "quant_gemm": _fuzz_quant_gemm,
    "paged_attention": _fuzz_paged_attention,
    "ragged_prefill": _fuzz_ragged_prefill,
}


@pytest.mark.parametrize("family", sorted(family_names()))
def test_every_family_has_a_fuzz_adapter(family):
    """Registering a kernel family without extending the fuzz harness
    is an error, not a gap."""
    assert family in ADAPTERS, \
        (f"family {family!r} is registered but has no differential fuzz "
         f"adapter — add one to tests/test_kernel_fuzz.py:ADAPTERS")


@pytest.mark.parametrize("trial", TRIALS)
@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("family", sorted(family_names()))
def test_differential_fuzz(family, case, trial):
    if family not in ADAPTERS:
        pytest.fail(f"no fuzz adapter for {family!r}")
    fam = get_family(family)
    sweeps = fam.sweep_problems() if fam.sweep_problems else [
        fam.example()[1]]
    prob = sweeps[case % len(sweeps)]
    rng, seed = _rng(family, case, trial)
    got, want, rtol, atol, desc = ADAPTERS[family](prob, rng)
    got, want = np.asarray(got, np.float32), np.asarray(want, np.float32)
    assert got.shape == want.shape, \
        f"{family}[{desc}] seed={seed}: shape {got.shape} != {want.shape}"
    np.testing.assert_allclose(
        got, want, rtol=rtol, atol=atol,
        err_msg=(f"{family} kernel diverged from oracle on {desc} — "
                 f"reproduce with stable_seed input "
                 f"'fuzz:{family}:{case}:{trial}' (seed={seed})"))
