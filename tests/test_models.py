"""Model zoo: per-arch smoke tests + decode/forward consistency."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import build, lm_loss

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _batch(cfg):
    b = {"tokens": jax.random.randint(KEY, (B, S), 2, cfg.vocab)}
    if cfg.frontend == "audio_frames":
        b["enc_embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.dtype(cfg.dtype))
    return b


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_smoke(name):
    """Reduced config: forward + loss finite, shapes right, grads flow."""
    cfg = configs.get_reduced(name)
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, metrics = lm_loss(model, params, batch, remat=False)
    assert np.isfinite(float(loss)), name
    if cfg.family in ("encdec", "audio"):
        logits, _ = model.apply(params, batch["tokens"],
                                enc_embeds=batch["enc_embeds"],
                                remat=False)
    else:
        logits, _ = model.apply(params, batch["tokens"], remat=False)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    g = jax.grad(lambda p: lm_loss(model, p, batch)[0])(params)
    gn = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x.astype(jnp.float32)))),
        g, 0.0)
    assert np.isfinite(gn) and gn > 0, name


@pytest.mark.parametrize("name", ["qwen3-1.7b", "deepseek-v2-lite-16b",
                                  "mamba2-780m", "recurrentgemma-2b"])
def test_decode_matches_full_forward(name):
    """prefill + decode_step must reproduce the full-forward logits for
    the next position — the KV-cache/state path is consistent with the
    training path (the serving-correctness invariant)."""
    cfg = configs.get_reduced(name)
    model = build(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 12), 2, cfg.vocab)

    # full forward over S+1 tokens: logits at position S-1 predict token S
    full_logits, _ = model.apply(params, toks, remat=False)

    if name == "recurrentgemma-2b":
        # hybrid prefill returns fresh states; replay tokens one by one
        cache = model.init_cache(1, 32)
        for t in range(toks.shape[1] - 1):
            step_logits, cache = model.decode_step(
                params, cache, toks[:, t:t + 1], jnp.int32(t))
        got = np.asarray(step_logits[0, -1], np.float32)
    elif name == "mamba2-780m":
        cache = model.init_cache(1, 32)
        for t in range(toks.shape[1] - 1):
            step_logits, cache = model.decode_step(
                params, cache, toks[:, t:t + 1], jnp.int32(t))
        got = np.asarray(step_logits[0, -1], np.float32)
    else:
        _, cache = model.prefill(params, toks[:, :-1], max_len=32)
        step_logits, _ = model.decode_step(
            params, cache, toks[:, -1:], jnp.int32(toks.shape[1] - 1))
        got = np.asarray(step_logits[0, -1], np.float32)
        # decode consumed token index S-1 -> predicts token S: position -1
        want = np.asarray(full_logits[0, -1], np.float32)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
        return

    # stepwise replay consumed tokens 0..S-2: matches position -2
    want = np.asarray(full_logits[0, -2], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_param_counts_plausible():
    cfg = configs.get_config("qwen3-1.7b")
    n = build(cfg).n_params
    assert 1.4e9 < n < 2.4e9, n
    cfg = configs.get_config("deepseek-v2-lite-16b")
    m = build(cfg)
    assert 13e9 < m.n_params < 18e9, m.n_params
    assert 1.5e9 < m.n_active_params < 4e9, m.n_active_params
    cfg = configs.get_config("mamba2-780m")
    n = build(cfg).n_params
    assert 0.5e9 < n < 1.1e9, n
    cfg = configs.get_config("chameleon-34b")
    n = build(cfg).n_params
    assert 28e9 < n < 40e9, n


def test_moe_router_balanced_aux():
    """Uniform logits -> aux loss ≈ 1 (perfectly balanced)."""
    from repro.models.moe import route
    cfg = configs.get_reduced("granite-moe-3b-a800m")
    model = build(cfg)
    params = model.init(KEY)
    x = jnp.zeros((512, cfg.d_model), jnp.float32)
    p = jax.tree.map(lambda a: a, params["blocks"]["moe"])
    p = jax.tree.map(lambda a: a[0], p)   # layer 0
    gates, idx, aux = route(p, x, cfg)
    assert gates.shape == (512, cfg.moe.top_k)
    assert float(jnp.abs(gates.sum(-1) - 1.0).max()) < 1e-5
