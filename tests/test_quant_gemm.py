"""quant_gemm family: scale-provenance invariants, stage attribution,
cost-model semantics, and the interpret-mode kernel vs the oracle."""
import numpy as np
import pytest

from repro.core.families import get_family
from repro.core.verify_engine import VerificationEngine

FAM = get_family("quant_gemm")
CFG = FAM.config_cls()                                # 128³ tiles
PROB = FAM.problem_cls(512, 512, 1024, group=256)     # 4 scale groups


class TestScaleProvenance:
    def test_good_config_proves_all_assertions(self):
        res = FAM.verify(CFG, PROB)
        assert res.hard_ok, res.render()

    def test_wrong_kslice_scale_yields_concrete_counterexample(self):
        """The acceptance property: a scale applied to the wrong K-slice
        must produce a concrete counterexample from the verify engine."""
        eng = VerificationEngine()
        res = eng.verify("quant_gemm", CFG, PROB,
                         inject_bug="a_scale_wrong_kslice")
        assert not res.hard_ok
        bad = [f for f in res.violations if f.counterexample is not None]
        assert bad, "expected a counterexample, not just a verdict"
        ce = bad[0].counterexample
        assert ce.env, "counterexample must name a concrete grid step"
        assert bad[0].stage == "solver"
        assert bad[0].repair_hint

    def test_scale_row_and_column_provenance_both_checked(self):
        for bug in ("a_scale_row_offset", "b_scale_stale"):
            res = FAM.verify(CFG, PROB, inject_bug=bug)
            assert not res.hard_ok, f"{bug} slipped through"

    def test_deferred_dequant_is_an_analysis_stage_catch(self):
        """Accumulating the group-tagged product raw (dequant after the
        reduction) collapses the carry to ⊤ — a lattice-level verdict."""
        eng = VerificationEngine()
        res = eng.verify("quant_gemm", CFG, PROB,
                         inject_bug="acc_depends_k")
        assert not res.hard_ok
        assert any(f.stage == "analysis" for f in res.violations)

    def test_group_must_be_tile_aligned(self):
        """bk ∤ group is a config-validity error surfaced as build-stage
        feedback (each K tile needs exactly one scale)."""
        eng = VerificationEngine()
        bad_cfg = FAM.config_cls(bk=96)
        res = eng.verify("quant_gemm", bad_cfg, PROB)
        assert res.build_error is not None and not res.hard_ok
        assert any(f.stage == "build" for f in res.violations)

    def test_single_group_problem_drops_group_bugs(self):
        small = FAM.problem_cls(256, 256, 128, group=128)
        menu = FAM.bugs_for(FAM.config_cls(), small)
        assert "a_scale_wrong_kslice" not in menu
        assert "b_scale_stale" not in menu
        assert "missing_init" in menu


class TestCostModel:
    def test_narrow_dtype_doubles_mxu_issue_rate(self):
        from repro.core.costs import peak_flops
        assert peak_flops("i8") == 2 * peak_flops("bf16")
        assert peak_flops("fp8") == 2 * peak_flops("bf16")

    def test_quant_compute_beats_bf16_gemm(self):
        gemm = get_family("gemm")
        dense = gemm.cost(gemm.config_cls(),
                          gemm.problem_cls(4096, 4096, 4096, "bf16"))
        quant = FAM.cost(FAM.config_cls(),
                         FAM.problem_cls(4096, 4096, 4096, group=128))
        assert quant.flops == dense.flops
        assert quant.compute_s < dense.compute_s
        assert quant.hbm_bytes < dense.hbm_bytes

    def test_group_aligned_k_skill_respects_group_bound(self):
        skill = next(s for s in FAM.skills if s.name == "group_aligned_k")
        steps = skill.contexts(FAM.config_cls(bk=64), PROB)
        assert steps, "bk=64 < group=256 should offer a widening step"
        for _, cfg in steps:
            assert PROB.group % cfg.bk == 0 and cfg.bk <= PROB.group


class TestKernel:
    def test_quantize_per_group_roundtrip(self):
        from repro.kernels.quant_gemm import quantize_per_group
        rng = np.random.default_rng(0)
        x = rng.normal(size=(64, 256)).astype(np.float32)
        q, s = quantize_per_group(x, 128, axis=1)
        assert np.asarray(q).dtype == np.int8
        assert s.shape == (64, 2)
        back = np.asarray(q, dtype=np.float32) * \
            np.repeat(np.asarray(s), 128, axis=1)
        assert np.allclose(back, x, atol=np.abs(x).max() / 100)

    def test_validated_entry_rejects_bad_config(self):
        import jax.numpy as jnp
        from repro.kernels.quant_gemm import (InvariantViolation,
                                              quant_matmul)
        a = jnp.zeros((128, 256), jnp.int8)
        b = jnp.zeros((256, 128), jnp.int8)
        sa = jnp.ones((128, 2), jnp.float32)
        sb = jnp.ones((2, 128), jnp.float32)
        with pytest.raises(InvariantViolation):
            quant_matmul(a, b, sa, sb, group=128,
                         cfg=FAM.config_cls(bk=96), interpret=True)

    @pytest.mark.slow
    def test_interpret_mode_matches_oracle(self):
        assert FAM.reference_check(FAM.config_cls(),
                                   FAM.problem_cls(256, 256, 512,
                                                   group=128))
