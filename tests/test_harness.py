"""Agentic harness: planner/selector/lowering/validator/ICRL behavior,
including the feedback-driven targeted-repair pipeline (paper §9.4)."""
import statistics

import pytest

from repro.core.harness import (KernelState, LoweredState, LoweringAgent,
                                Planner, PlannerParams, Selector,
                                Validator, icrl_train, optimize_kernel)
from repro.core.harness.costmodel import estimate
from repro.core.invariants import (FlashAttentionConfig,
                                   FlashAttentionProblem, GemmConfig,
                                   GemmProblem, MoEConfig, MoEProblem)

GEMM = KernelState("gemm", GemmConfig(), GemmProblem(8192, 8192, 8192,
                                                     "bf16"))
FA = KernelState("flash_attention",
                 FlashAttentionConfig(block_q=8, causal_block_skip=False),
                 FlashAttentionProblem(16, 8, 1, 8192, 8192, 128, True,
                                       "bf16"))
MOE = KernelState("moe", MoEConfig(block_t=8),
                  MoEProblem(16384, 7168, 2048, 32, 8, "bf16"))


def _fresh(s):
    return KernelState(s.family, s.cfg, s.prob).refresh()


class TestPlanner:
    def test_proposals_ranked_and_scored(self):
        props = Planner().propose(_fresh(GEMM))
        assert props
        assert all(props[i].score >= props[i + 1].score
                   for i in range(len(props) - 1))

    def test_bias_changes_ranking(self):
        st = _fresh(GEMM)
        p0 = Planner().propose(st)
        params = PlannerParams(skill_bias={"stagger_k": 10.0})
        p1 = Planner(params).propose(st)
        assert p1[0].skill.name == "stagger_k"
        assert p0[0].skill.name != "stagger_k" or True


class TestSelector:
    def test_low_temperature_greedy(self):
        props = Planner().propose(_fresh(GEMM))
        sel = Selector(temperature=1e-6, seed=0)
        assert sel.select(props).score == props[0].score

    def test_deterministic_given_seed(self):
        props = Planner().propose(_fresh(GEMM))
        a = Selector(temperature=0.5, seed=42).select(props)
        b = Selector(temperature=0.5, seed=42).select(props)
        assert a is b


class TestHillclimb:
    @pytest.mark.parametrize("task,min_speedup", [
        (GEMM, 2.0), (FA, 3.0), (MOE, 1.5)])
    def test_improves_each_family(self, task, min_speedup):
        res = optimize_kernel(_fresh(task), planner=Planner(),
                              selector=Selector(temperature=0.1, seed=1),
                              validator=Validator(), iterations=20)
        assert res.speedup >= min_speedup, (task.family, res.speedup)

    def test_all_accepted_configs_pass_invariants(self):
        res = optimize_kernel(_fresh(GEMM), planner=Planner(),
                              selector=Selector(seed=2),
                              validator=Validator(), iterations=12)
        from repro.core.invariants import verify_gemm
        assert verify_gemm(res.best_state.cfg, res.best_state.prob).hard_ok


class TestFaultModelAndInvariants:
    def test_static_catch_is_cheaper_than_unit_tests(self):
        tasks = [GEMM, FA, MOE]
        _, on = icrl_train(tasks, episodes=5, iterations=6, seed=0,
                           fault_model=True, use_invariants=True)
        _, off = icrl_train(tasks, episodes=5, iterations=6, seed=0,
                            fault_model=True, use_invariants=False)
        cost_on = statistics.mean(r.cost_units for r in on)
        cost_off = statistics.mean(r.cost_units for r in off)
        assert cost_on < cost_off

    def test_icrl_updates_theta_and_logs_lessons(self):
        params, _ = icrl_train([GEMM], episodes=3, iterations=5, seed=1,
                               fault_model=False)
        assert params.skill_bias
        assert params.lessons

    def test_silent_corruption_only_without_invariants(self):
        # with invariants every injected bug is caught statically
        lo = LoweringAgent(fault_model=True, seed=5)
        val = Validator(use_invariants=True)
        st = _fresh(GEMM)
        planner = Planner()
        bad = 0
        for i in range(10):
            prop = Selector(seed=i).select(planner.propose(st))
            lowered = lo.apply(st, prop)
            v = val.evaluate(lowered, st.est.time_s)
            if lowered.latent_bug is not None:
                assert v.caught_static, "invariants missed an injected bug"
                bad += 1
        assert bad > 0, "fault model never fired (seed issue)"


class TestTargetedRepair:
    """repair() consumes Verdict.feedback: counterexamples matched against
    the family's BugSignature ground truth pick *which* latent bug to fix,
    with fix probability scaled by match specificity."""

    def _plant(self, bug, seed=0):
        st = _fresh(GEMM)
        lowered = LoweredState(st, bug, applied="test")
        verdict = Validator(use_invariants=True).evaluate(lowered,
                                                          st.est.time_s)
        assert verdict.caught_static and verdict.feedback
        return lowered, verdict

    def test_exact_feedback_targets_the_right_bug(self):
        from repro.core.families import MATCH_EXACT
        lowered, verdict = self._plant("grid_short")
        agent = LoweringAgent(seed=3)
        _, att = agent.repair(lowered, feedback=verdict.feedback)
        assert att.targeted and att.specificity == MATCH_EXACT
        assert att.candidates == ["grid_short"]
        assert att.picked == "grid_short"
        assert att.stage == "solver"
        assert "assert_coverage" in att.assertion

    def test_ambiguous_fingerprint_yields_candidate_set(self):
        # acc_depends_k and missing_init share the ⊤-carry fingerprint
        lowered, verdict = self._plant("missing_init")
        _, att = LoweringAgent(seed=1).repair(lowered,
                                              feedback=verdict.feedback)
        assert sorted(att.candidates) == ["acc_depends_k", "missing_init"]
        assert att.stage == "analysis"

    def test_blind_repair_without_feedback(self):
        lowered, _ = self._plant("grid_short")
        _, att = LoweringAgent(seed=2).repair(lowered, feedback=())
        assert not att.targeted and att.stage == ""
        assert att.picked is not None

    def test_caught_stage_attribution(self):
        _, v_solver = self._plant("swap_b_index")
        assert v_solver.caught_stage == "solver"
        _, v_analysis = self._plant("missing_init")
        assert v_analysis.caught_stage == "analysis"

    def test_targeted_beats_blind_on_repairs_to_green(self):
        def episodes(targeted, n=60):
            validator = Validator(use_invariants=True)
            greens = 0
            for s in range(n):
                agent = LoweringAgent(seed=s)
                st = _fresh(GEMM)
                lowered = LoweredState(st, "grid_short", applied="t")
                verdict = validator.evaluate(lowered, st.est.time_s)
                for _ in range(2):     # optimize_kernel's default budget
                    if verdict.ok:
                        break
                    fb = verdict.feedback if targeted else ()
                    lowered, _ = agent.repair(lowered, feedback=fb)
                    verdict = validator.evaluate(lowered, st.est.time_s)
                greens += verdict.ok
            return greens
        assert episodes(True) > episodes(False), \
            "feedback-matched repair must out-repair blind repair"


class TestStageAttributedLearning:
    def test_repair_outcomes_threaded_through_history(self):
        _, results = icrl_train([GEMM], episodes=4, iterations=6, seed=3,
                                fault_model=True, use_invariants=True)
        atts = [a for res in results for rec in res.history
                for a in rec.repairs]
        assert atts, "fault model never forced a repair (seed issue)"
        assert any(a.targeted for a in atts)
        summary = next(r.repair_summary() for r in results
                       if r.repair_summary())
        for stage, row in summary.items():
            assert row["attempts"] >= row["fixed"]

    def test_icrl_records_assertion_strikes(self):
        params, _ = icrl_train([GEMM], episodes=5, iterations=6,
                               seed=3, fault_model=True,
                               use_invariants=True)
        assert params.assertion_strikes, \
            "static catches must record assertion strikes"

    def test_lessons_are_stage_attributed(self):
        from repro.core.harness import StepRecord
        from repro.core.harness.icrl import (analyze, parameter_update,
                                             policy_eval)
        from repro.core.harness.validator import Verdict
        from repro.core.verify_engine import Feedback
        fb = [Feedback("solver", "gemm[x][10]:assert_coverage(C)", False)]
        buffer = [
            StepRecord("stagger_k", "c",
                       Verdict(False, caught_static=True, reward=-0.55,
                               feedback=fb, caught_stage="solver"),
                       False, 0.0),
            StepRecord("retile", "c", Verdict(True, reward=0.5), True, 0.0),
        ]
        params = parameter_update(PlannerParams(),
                                  analyze(policy_eval(buffer)),
                                  buffer=buffer)
        assert params.assertion_strikes["stagger_k"][
            "assert_coverage(C)"] == 1
        assert any("assert_coverage(C) at the solver stage" in lesson
                   for lesson in params.lessons)

    def test_strike_penalty_downweights_repeat_offenders(self):
        st = _fresh(GEMM)
        base = Planner().propose(st)
        top = base[0].skill.name
        params = PlannerParams()
        for _ in range(6):
            params.strike(top, "assert_coverage(C)")
        biased = Planner(params).propose(st)
        top_score = {p.skill.name: p.score for p in biased}
        assert top_score[top] < base[0].score, \
            "repeatedly tripping one assertion must cost planner score"


class TestCostModel:
    def test_bigger_tiles_cut_memory_traffic(self):
        small = estimate("gemm", GemmConfig(128, 128, 128),
                         GemmProblem(8192, 8192, 8192))
        big = estimate("gemm", GemmConfig(512, 512, 128),
                       GemmProblem(8192, 8192, 8192))
        assert big.hbm_bytes < small.hbm_bytes

    def test_causal_skip_halves_flops(self):
        prob = FlashAttentionProblem(8, 8, 1, 8192, 8192, 128)
        on = estimate("flash_attention",
                      FlashAttentionConfig(causal_block_skip=True), prob)
        off = estimate("flash_attention",
                       FlashAttentionConfig(causal_block_skip=False), prob)
        assert abs(on.flops / off.flops - 0.5) < 1e-6
