"""Agentic harness: planner/selector/lowering/validator/ICRL behavior."""
import statistics

import pytest

from repro.core.harness import (KernelState, LoweringAgent, Planner,
                                PlannerParams, Selector, Validator,
                                icrl_train, optimize_kernel)
from repro.core.harness.costmodel import estimate
from repro.core.invariants import (FlashAttentionConfig,
                                   FlashAttentionProblem, GemmConfig,
                                   GemmProblem, MoEConfig, MoEProblem)

GEMM = KernelState("gemm", GemmConfig(), GemmProblem(8192, 8192, 8192,
                                                     "bf16"))
FA = KernelState("flash_attention",
                 FlashAttentionConfig(block_q=8, causal_block_skip=False),
                 FlashAttentionProblem(16, 8, 1, 8192, 8192, 128, True,
                                       "bf16"))
MOE = KernelState("moe", MoEConfig(block_t=8),
                  MoEProblem(16384, 7168, 2048, 32, 8, "bf16"))


def _fresh(s):
    return KernelState(s.family, s.cfg, s.prob).refresh()


class TestPlanner:
    def test_proposals_ranked_and_scored(self):
        props = Planner().propose(_fresh(GEMM))
        assert props
        assert all(props[i].score >= props[i + 1].score
                   for i in range(len(props) - 1))

    def test_bias_changes_ranking(self):
        st = _fresh(GEMM)
        p0 = Planner().propose(st)
        params = PlannerParams(skill_bias={"stagger_k": 10.0})
        p1 = Planner(params).propose(st)
        assert p1[0].skill.name == "stagger_k"
        assert p0[0].skill.name != "stagger_k" or True


class TestSelector:
    def test_low_temperature_greedy(self):
        props = Planner().propose(_fresh(GEMM))
        sel = Selector(temperature=1e-6, seed=0)
        assert sel.select(props).score == props[0].score

    def test_deterministic_given_seed(self):
        props = Planner().propose(_fresh(GEMM))
        a = Selector(temperature=0.5, seed=42).select(props)
        b = Selector(temperature=0.5, seed=42).select(props)
        assert a is b


class TestHillclimb:
    @pytest.mark.parametrize("task,min_speedup", [
        (GEMM, 2.0), (FA, 3.0), (MOE, 1.5)])
    def test_improves_each_family(self, task, min_speedup):
        res = optimize_kernel(_fresh(task), planner=Planner(),
                              selector=Selector(temperature=0.1, seed=1),
                              validator=Validator(), iterations=20)
        assert res.speedup >= min_speedup, (task.family, res.speedup)

    def test_all_accepted_configs_pass_invariants(self):
        res = optimize_kernel(_fresh(GEMM), planner=Planner(),
                              selector=Selector(seed=2),
                              validator=Validator(), iterations=12)
        from repro.core.invariants import verify_gemm
        assert verify_gemm(res.best_state.cfg, res.best_state.prob).hard_ok


class TestFaultModelAndInvariants:
    def test_static_catch_is_cheaper_than_unit_tests(self):
        tasks = [GEMM, FA, MOE]
        _, on = icrl_train(tasks, episodes=5, iterations=6, seed=0,
                           fault_model=True, use_invariants=True)
        _, off = icrl_train(tasks, episodes=5, iterations=6, seed=0,
                            fault_model=True, use_invariants=False)
        cost_on = statistics.mean(r.cost_units for r in on)
        cost_off = statistics.mean(r.cost_units for r in off)
        assert cost_on < cost_off

    def test_icrl_updates_theta_and_logs_lessons(self):
        params, _ = icrl_train([GEMM], episodes=3, iterations=5, seed=1,
                               fault_model=False)
        assert params.skill_bias
        assert params.lessons

    def test_silent_corruption_only_without_invariants(self):
        # with invariants every injected bug is caught statically
        lo = LoweringAgent(fault_model=True, seed=5)
        val = Validator(use_invariants=True)
        st = _fresh(GEMM)
        planner = Planner()
        bad = 0
        for i in range(10):
            prop = Selector(seed=i).select(planner.propose(st))
            lowered = lo.apply(st, prop)
            v = val.evaluate(lowered, st.est.time_s)
            if lowered.latent_bug is not None:
                assert v.caught_static, "invariants missed an injected bug"
                bad += 1
        assert bad > 0, "fault model never fired (seed issue)"


class TestCostModel:
    def test_bigger_tiles_cut_memory_traffic(self):
        small = estimate("gemm", GemmConfig(128, 128, 128),
                         GemmProblem(8192, 8192, 8192))
        big = estimate("gemm", GemmConfig(512, 512, 128),
                       GemmProblem(8192, 8192, 8192))
        assert big.hbm_bytes < small.hbm_bytes

    def test_causal_skip_halves_flops(self):
        prob = FlashAttentionProblem(8, 8, 1, 8192, 8192, 128)
        on = estimate("flash_attention",
                      FlashAttentionConfig(causal_block_skip=True), prob)
        off = estimate("flash_attention",
                       FlashAttentionConfig(causal_block_skip=False), prob)
        assert abs(on.flops / off.flops - 0.5) < 1e-6
