"""Property tests over the kernel-config space (hypothesis):

* soundness   — every *valid* config passes invariant validation
                (no false rejections blocking the optimizer), and
* completeness over the modeled fault space — every injected bug class is
                caught for every sampled config.

These are the system-level statements behind the paper's Table 3: the
static layer's verdicts are trustworthy enough to act as dense rewards.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — pip install -r requirements-dev.txt")
from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.core.invariants import (FlashAttentionConfig,
                                   FlashAttentionProblem, GemmConfig,
                                   GemmProblem, MoEConfig, MoEProblem,
                                   SSDConfig, SSDProblem, verify_gemm,
                                   verify_flash_attention, verify_moe,
                                   verify_ssd)

pow2 = lambda lo, hi: st.sampled_from(
    [2 ** i for i in range(lo, hi + 1)])


@st.composite
def gemm_cases(draw):
    cfg = GemmConfig(bm=draw(pow2(4, 9)), bn=draw(pow2(4, 9)),
                     bk=draw(pow2(5, 9)),
                     split_k=draw(st.sampled_from([1, 1, 2, 4])),
                     stagger_k=draw(st.booleans()))
    m = draw(pow2(9, 12))
    n = draw(pow2(9, 12))
    k = draw(pow2(9, 12))
    assume(cfg.split_k == 1 or (k // cfg.bk) % cfg.split_k == 0)
    assume(k >= cfg.bk * cfg.split_k)
    if cfg.split_k > 1:
        cfg = GemmConfig(cfg.bm, cfg.bn, cfg.bk, cfg.split_k, False)
    return cfg, GemmProblem(m, n, k, "bf16")


@given(gemm_cases())
@settings(max_examples=25, deadline=None)
def test_valid_gemm_configs_never_rejected(case):
    cfg, prob = case
    assert verify_gemm(cfg, prob).hard_ok


@given(gemm_cases(), st.sampled_from(
    ["swap_b_index", "acc_depends_k", "grid_short", "missing_init"]))
@settings(max_examples=20, deadline=None)
def test_gemm_bugs_always_caught(case, bug):
    cfg, prob = case
    assume(not (bug == "grid_short" and prob.m <= cfg.bm))
    # a single-step reduction has no carried accumulator dependence — the
    # bug is vacuous at nk == 1 (hypothesis-discovered edge)
    assume(not (bug == "acc_depends_k"
                and prob.k // (cfg.bk * cfg.split_k) < 2))
    assert not verify_gemm(cfg, prob, inject_bug=bug).hard_ok


@st.composite
def fa_cases(draw):
    hkv = draw(st.sampled_from([1, 2, 4]))
    group = draw(st.sampled_from([1, 2, 4]))
    cfg = FlashAttentionConfig(block_q=draw(pow2(4, 9)),
                               block_kv=draw(pow2(4, 8)),
                               causal_block_skip=draw(st.booleans()))
    prob = FlashAttentionProblem(
        batch=draw(st.sampled_from([1, 4, 16])), q_heads=hkv * group,
        kv_heads=hkv, seq_q=draw(pow2(10, 13)), seq_kv=draw(pow2(10, 13)),
        head_dim=draw(st.sampled_from([64, 128, 256])), causal=True,
        dtype="bf16")
    return cfg, prob


@given(fa_cases())
@settings(max_examples=25, deadline=None)
def test_valid_fa_configs_never_rejected(case):
    cfg, prob = case
    assert verify_flash_attention(cfg, prob).hard_ok


@given(fa_cases(), st.sampled_from(["wrong_kv_head", "m_depends_kv",
                                    "q_block_offset"]))
@settings(max_examples=20, deadline=None)
def test_fa_bugs_always_caught(case, bug):
    cfg, prob = case
    assume(not (bug == "wrong_kv_head" and prob.q_heads == prob.kv_heads))
    assert not verify_flash_attention(cfg, prob, inject_bug=bug).hard_ok


@st.composite
def moe_cases(draw):
    cfg = MoEConfig(block_t=draw(pow2(3, 8)), block_f=draw(pow2(7, 10)),
                    fuse_gate=draw(st.booleans()))
    d_ff = cfg.block_f * draw(st.sampled_from([1, 2, 4]))
    prob = MoEProblem(tokens=draw(pow2(10, 14)),
                      d_model=draw(st.sampled_from([512, 1024, 4096])),
                      d_ff=d_ff,
                      n_experts=draw(st.sampled_from([8, 16, 64])),
                      top_k=draw(st.sampled_from([1, 2, 6, 8])),
                      dtype="bf16")
    return cfg, prob


@given(moe_cases())
@settings(max_examples=20, deadline=None)
def test_valid_moe_configs_never_rejected(case):
    cfg, prob = case
    assert verify_moe(cfg, prob).hard_ok


@given(moe_cases(), st.sampled_from(
    ["w_by_block_index", "combine_other_table", "gate_unpermuted",
     "down_f_offset", "y_depends_f"]))
@settings(max_examples=20, deadline=None)
def test_moe_bugs_always_caught(case, bug):
    cfg, prob = case
    # an unfused gate has no in-kernel gate gather to corrupt
    assume(not (bug == "gate_unpermuted" and not cfg.fuse_gate))
    assert not verify_moe(cfg, prob, inject_bug=bug).hard_ok


@st.composite
def ssd_cases(draw):
    q = draw(st.sampled_from([32, 64, 128, 256]))
    prob = SSDProblem(batch_heads=draw(st.sampled_from([8, 64, 384])),
                      seq=q * draw(st.sampled_from([2, 8, 32])),
                      head_dim=draw(st.sampled_from([32, 64, 128])),
                      d_state=draw(st.sampled_from([64, 128])))
    return SSDConfig(chunk=q), prob


@given(ssd_cases())
@settings(max_examples=15, deadline=None)
def test_valid_ssd_configs_never_rejected(case):
    cfg, prob = case
    assert verify_ssd(cfg, prob).hard_ok


@given(ssd_cases(), st.sampled_from(["b_chunk_offset", "state_depends_c",
                                     "xb_mismatch"]))
@settings(max_examples=15, deadline=None)
def test_ssd_bugs_always_caught(case, bug):
    cfg, prob = case
    assume(prob.seq // cfg.chunk >= 2)
    assert not verify_ssd(cfg, prob, inject_bug=bug).hard_ok
